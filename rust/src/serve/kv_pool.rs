//! Paged KV pool: fixed-size pages, a free-page allocator, and per-sequence
//! page tables — the vLLM-style storage layer under the generation server.
//!
//! The previous pool reserved `prompt + max_new − 1` contiguous rows per
//! slot at admission, so worst-case sizing — not actual usage — gated batch
//! depth.  Here a sequence owns a **page table** (a list of page ids); pages
//! hold `page_size` positions × all layers × K and V, are claimed from a
//! LIFO free list one at a time as the sequence grows ("reserve the first
//! page, fault in the rest"), and are refcounted so prompt-prefix pages can
//! be shared across sequences ([`super::prefix::PrefixTrie`]).  Writing into
//! a shared page copies it first (copy-on-write), so sharing can never
//! corrupt a neighbor's history.
//!
//! **Per-layer row widths.**  Each layer stores K rows of `wk[l]` floats and
//! V rows of `wv[l]` floats.  Uncompressed, every width is `d_model`; under
//! KV-cache compression ([`crate::model::kvc::KvCompression`], built by
//! [`KvPool::with_kvc`]) a compressed layer's width is its latent rank `r`,
//! so pages shrink by ~`r/d` and the same byte budget holds proportionally
//! more positions.  The pool stores whatever rows the step hands it — it
//! does not know (or care) whether a row is a full K/V vector or a latent.
//!
//! The pool is owned by the scheduler thread
//! ([`super::batcher::serve_generation`]); it is deliberately not `Sync` —
//! every refcount and page-table mutation happens *between* decode steps on
//! that one thread, which is what keeps the whole subsystem lock-free.
//!
//! Storage layout: page `p`, layer `l`, in-page position `s` keeps its K row
//! at `k_pages[p][k_base[l] + s * wk[l] ..][..wk[l]]` with `k_base[l] =
//! page_size · Σ_{j<l} wk[j]` (V likewise) — contiguous per `(page, layer)`,
//! so a history gather is one `copy_from_slice` per page and a history that
//! fits one page is borrowed without copying ([`KvPool::hist_slices`]).

use crate::model::config::ModelConfig;
use crate::model::kvc::KvCompression;

/// Index of a page in the pool's backing storage.
pub type PageId = usize;
/// Handle of an admitted sequence (a slab index; recycled after release).
pub type SeqId = usize;

/// One sequence's pool-side state.
#[derive(Debug, Default)]
struct SeqState {
    /// Page ids covering positions `[i * page_size, (i+1) * page_size)`.
    table: Vec<PageId>,
    /// Committed (valid) positions.
    len: usize,
    live: bool,
}

/// Paged K/V storage shared by all concurrent sequences.
#[derive(Debug)]
pub struct KvPool {
    page_size: usize,
    /// Per-layer K row width (latent rank under compression, else d_model).
    wk: Vec<usize>,
    /// Per-layer V row width.
    wv: Vec<usize>,
    /// Per-layer K offset within a page: `page_size · Σ_{j<l} wk[j]`.
    k_base: Vec<usize>,
    /// Per-layer V offset within a page.
    v_base: Vec<usize>,
    /// Elements per K page (`page_size · Σ wk`).
    k_elems: usize,
    /// Elements per V page (`page_size · Σ wv`).
    v_elems: usize,
    /// `[page]` → `[k_elems]` K rows.
    k_pages: Vec<Vec<f32>>,
    /// `[page]` → `[v_elems]` V rows.
    v_pages: Vec<Vec<f32>>,
    /// Reference count per page (sequences + trie entries).
    refs: Vec<u32>,
    /// LIFO free-page list — claim/release are O(1).
    free: Vec<PageId>,
    /// Sequence slab + its free list.
    seqs: Vec<SeqState>,
    seq_free: Vec<SeqId>,
}

impl KvPool {
    /// Pool with `pages` fixed-size pages of `page_size` positions each,
    /// uniform `d_model`-wide rows (the uncompressed cache).  Allocates
    /// everything up front: `2 · pages · layers · page_size · d_model`
    /// f32s; the hot loop never allocates page storage.
    pub fn new(cfg: &ModelConfig, pages: usize, page_size: usize) -> KvPool {
        KvPool::with_kvc(cfg, pages, page_size, None)
    }

    /// Pool whose per-layer row widths follow `kvc`: compressed layers
    /// store rank-wide latents, identity layers full `d_model` rows.
    /// `None` (and the all-identity compression) is exactly [`KvPool::new`].
    pub fn with_kvc(
        cfg: &ModelConfig,
        pages: usize,
        page_size: usize,
        kvc: Option<&KvCompression>,
    ) -> KvPool {
        assert!(pages > 0, "KvPool needs at least one page");
        assert!(page_size > 0, "KvPool needs at least one position per page");
        let d = cfg.d_model;
        let layers = cfg.n_layers;
        let wk: Vec<usize> =
            (0..layers).map(|l| kvc.map_or(d, |c| c.width_k(l, d))).collect();
        let wv: Vec<usize> =
            (0..layers).map(|l| kvc.map_or(d, |c| c.width_v(l, d))).collect();
        let base = |ws: &[usize]| -> Vec<usize> {
            let mut acc = 0usize;
            ws.iter()
                .map(|w| {
                    let b = acc * page_size;
                    acc += w;
                    b
                })
                .collect()
        };
        let (k_base, v_base) = (base(&wk), base(&wv));
        let k_elems = page_size * wk.iter().sum::<usize>();
        let v_elems = page_size * wv.iter().sum::<usize>();
        KvPool {
            page_size,
            wk,
            wv,
            k_base,
            v_base,
            k_elems,
            v_elems,
            k_pages: (0..pages).map(|_| vec![0.0f32; k_elems]).collect(),
            v_pages: (0..pages).map(|_| vec![0.0f32; v_elems]).collect(),
            refs: vec![0; pages],
            free: (0..pages).rev().collect(),
            seqs: Vec::new(),
            seq_free: Vec::new(),
        }
    }

    /// Total page count.
    pub fn pages(&self) -> usize {
        self.refs.len()
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total positions the pool can hold (`pages · page_size`).
    pub fn capacity(&self) -> usize {
        self.pages() * self.page_size
    }

    /// Stored K row width of `layer` (latent rank under compression).
    pub fn width_k(&self, layer: usize) -> usize {
        self.wk[layer]
    }

    /// Stored V row width of `layer`.
    pub fn width_v(&self, layer: usize) -> usize {
        self.wv[layer]
    }

    /// Bytes of K+V storage per page — the slots-per-GB denominator.
    /// Compression shrinks exactly this number (`Σ(wk+wv) · page_size ·
    /// 4` bytes); page count and table overheads are unchanged.
    pub fn page_bytes(&self) -> usize {
        4 * (self.k_elems + self.v_elems)
    }

    /// Pages currently on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently referenced by at least one sequence or trie entry.
    pub fn pages_in_use(&self) -> usize {
        self.pages() - self.free.len()
    }

    /// Live sequence count.
    pub fn live_seqs(&self) -> usize {
        self.seqs.iter().filter(|s| s.live).count()
    }

    // ---- sequence lifecycle -------------------------------------------

    /// Admit a new empty sequence.  Never fails and claims no page — pages
    /// fault in on first write ([`KvPool::prepare`]).
    pub fn new_seq(&mut self) -> SeqId {
        self.fork_seq(&[])
    }

    /// Admit a sequence whose first `shared.len() · page_size` positions
    /// alias already-populated pages (prompt-prefix sharing): each shared
    /// page's refcount is bumped and the new sequence starts with
    /// `len == shared.len() · page_size` committed positions.
    pub fn fork_seq(&mut self, shared: &[PageId]) -> SeqId {
        for &p in shared {
            debug_assert!(self.refs[p] > 0, "fork over unreferenced page {p}");
            self.refs[p] += 1;
        }
        let state = SeqState {
            table: shared.to_vec(),
            len: shared.len() * self.page_size,
            live: true,
        };
        match self.seq_free.pop() {
            Some(id) => {
                self.seqs[id] = state;
                id
            }
            None => {
                self.seqs.push(state);
                self.seqs.len() - 1
            }
        }
    }

    /// Retire a sequence: every page it references is unreferenced (pages
    /// shared with other sequences or the prefix trie survive), the handle
    /// is recycled.  O(table length).
    pub fn release_seq(&mut self, seq: SeqId) {
        debug_assert!(self.seqs[seq].live, "double release of sequence {seq}");
        let table = std::mem::take(&mut self.seqs[seq].table);
        for p in table {
            self.unref_page(p);
        }
        self.seqs[seq].len = 0;
        self.seqs[seq].live = false;
        self.seq_free.push(seq);
    }

    /// Committed positions of `seq`.
    pub fn len(&self, seq: SeqId) -> usize {
        self.seqs[seq].len
    }

    /// The page covering table index `idx` of `seq` (for trie registration).
    pub fn page_at(&self, seq: SeqId, idx: usize) -> PageId {
        self.seqs[seq].table[idx]
    }

    /// Pages currently in `seq`'s table.
    pub fn seq_pages(&self, seq: SeqId) -> usize {
        self.seqs[seq].table.len()
    }

    /// Does any other holder (sequence or trie) share one of `seq`'s pages?
    /// Preemption prefers victims where this is `false` — releasing them
    /// returns every one of their pages to the free list.
    pub fn seq_is_shared(&self, seq: SeqId) -> bool {
        self.seqs[seq].table.iter().any(|&p| self.refs[p] > 1)
    }

    // ---- page references (prefix trie holds pages too) ----------------

    /// Refcount of `page` (tests + preemption heuristics).
    pub fn page_refs(&self, page: PageId) -> u32 {
        self.refs[page]
    }

    /// Add a reference to an already-referenced page (the prefix trie
    /// pinning a registered prompt page).
    pub fn ref_page(&mut self, page: PageId) {
        debug_assert!(self.refs[page] > 0, "ref of unreferenced page {page}");
        self.refs[page] += 1;
    }

    /// Drop one reference to `page`; at zero the page returns to the free
    /// list (storage retained, overwritten by the next claimant).  Returns
    /// `true` when the page was actually freed.
    pub fn unref_page(&mut self, page: PageId) -> bool {
        debug_assert!(self.refs[page] > 0, "unref of free page {page}");
        self.refs[page] -= 1;
        if self.refs[page] == 0 {
            self.free.push(page);
            true
        } else {
            false
        }
    }

    // ---- growth: fault-in + copy-on-write -----------------------------

    /// Make position `pos` of `seq` writable: fault in a fresh page when
    /// `pos` opens a new page, copy-on-write when its page is shared.
    /// Returns `None` — with the page table untouched — when the free list
    /// is empty and an allocation was needed (the scheduler then evicts
    /// prefix-trie pages or preempts a sequence and retries).  Positions
    /// must grow contiguously: `pos` at most one page past the table end.
    pub fn prepare(&mut self, seq: SeqId, pos: usize) -> Option<()> {
        let idx = pos / self.page_size;
        let table_len = self.seqs[seq].table.len();
        debug_assert!(
            idx <= table_len,
            "sequence {seq}: position {pos} skips pages (table holds {table_len})"
        );
        if idx == table_len {
            // Fault in a fresh page.  Check-before-mutate: exhaustion must
            // leave every page table exactly as it was.
            let page = self.free.pop()?;
            self.refs[page] = 1;
            self.seqs[seq].table.push(page);
            return Some(());
        }
        let page = self.seqs[seq].table[idx];
        if self.refs[page] > 1 {
            // Copy-on-write: this sequence is about to diverge from the
            // other holders of `page`.  Copies exactly once — afterwards the
            // sequence owns the copy alone (refcount 1).
            let fresh = self.free.pop()?;
            let (src_k, dst_k) = two_pages(&mut self.k_pages, page, fresh);
            dst_k.copy_from_slice(src_k);
            let (src_v, dst_v) = two_pages(&mut self.v_pages, page, fresh);
            dst_v.copy_from_slice(src_v);
            self.refs[fresh] = 1;
            self.refs[page] -= 1;
            debug_assert!(self.refs[page] > 0);
            self.seqs[seq].table[idx] = fresh;
        }
        Some(())
    }

    /// Write the K/V rows of `(seq, layer)` at position `pos` — `k_row` is
    /// `wk[layer]` wide, `v_row` `wv[layer]` wide (latents under
    /// compression).  The page must have been made writable by
    /// [`KvPool::prepare`].
    pub fn push_row(&mut self, seq: SeqId, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let (wk, wv) = (self.wk[layer], self.wv[layer]);
        debug_assert_eq!(k_row.len(), wk);
        debug_assert_eq!(v_row.len(), wv);
        let idx = pos / self.page_size;
        assert!(
            idx < self.seqs[seq].table.len(),
            "sequence {seq}: position {pos} written without prepare()"
        );
        let page = self.seqs[seq].table[idx];
        debug_assert_eq!(
            self.refs[page], 1,
            "write into shared page {page} (prepare() skipped the CoW?)"
        );
        let s = pos % self.page_size;
        let ko = self.k_base[layer] + s * wk;
        self.k_pages[page][ko..ko + wk].copy_from_slice(k_row);
        let vo = self.v_base[layer] + s * wv;
        self.v_pages[page][vo..vo + wv].copy_from_slice(v_row);
    }

    /// Commit `seq`'s valid-position count.  Growth requires the covering
    /// pages to exist; truncation releases whole pages past the new end.
    pub fn set_len(&mut self, seq: SeqId, len: usize) {
        let need = len.div_ceil(self.page_size);
        let have = self.seqs[seq].table.len();
        assert!(
            need <= have,
            "sequence {seq}: set_len({len}) needs {need} pages, table holds {have}"
        );
        // `pop()` cannot observe an empty table here (the loop guard holds
        // `len > need >= 0`), but the scheduler thread must never panic on
        // a pool operation — degrade to stopping the truncation instead.
        while self.seqs[seq].table.len() > need {
            let Some(page) = self.seqs[seq].table.pop() else { break };
            self.unref_page(page);
        }
        self.seqs[seq].len = len;
    }

    // ---- history views ------------------------------------------------

    /// Borrow the K/V rows for positions `[base, t_now)` of `(seq, layer)`
    /// when they live in ONE page (`base` page-aligned) — the no-copy fast
    /// path the decode step takes for short histories and narrow attention
    /// windows.  `None` when the span crosses a page boundary.  Row widths
    /// are `wk[layer]`/`wv[layer]`.
    pub fn hist_slices(&self, seq: SeqId, layer: usize, base: usize, t_now: usize) -> Option<(&[f32], &[f32])> {
        debug_assert_eq!(base % self.page_size, 0, "base must be page-aligned");
        // Mid-step reads run ahead of the committed length (set_len lands
        // at the very end of the step), so bound against tabled pages.
        debug_assert!(base < t_now && t_now <= self.seqs[seq].table.len() * self.page_size);
        if t_now - base > self.page_size {
            return None;
        }
        let idx = base / self.page_size;
        if t_now > (idx + 1) * self.page_size {
            return None;
        }
        let page = self.seqs[seq].table[idx];
        let rows = t_now - base;
        let ko = self.k_base[layer];
        let vo = self.v_base[layer];
        Some((
            &self.k_pages[page][ko..ko + rows * self.wk[layer]],
            &self.v_pages[page][vo..vo + rows * self.wv[layer]],
        ))
    }

    /// Copy the K/V rows for positions `[base, t_now)` of `(seq, layer)`
    /// into `k_out`/`v_out` (cleared first; `base` page-aligned).  One
    /// `copy_from_slice` per touched page — the layout keeps each page's
    /// per-layer rows contiguous.
    pub fn gather_hist(
        &self,
        seq: SeqId,
        layer: usize,
        base: usize,
        t_now: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        debug_assert_eq!(base % self.page_size, 0, "base must be page-aligned");
        debug_assert!(base < t_now && t_now <= self.seqs[seq].table.len() * self.page_size);
        let (wk, wv) = (self.wk[layer], self.wv[layer]);
        k_out.clear();
        v_out.clear();
        k_out.reserve((t_now - base) * wk);
        v_out.reserve((t_now - base) * wv);
        let mut pos = base;
        while pos < t_now {
            let idx = pos / self.page_size;
            let page = self.seqs[seq].table[idx];
            let take = ((idx + 1) * self.page_size).min(t_now) - pos;
            let s = pos % self.page_size;
            let ko = self.k_base[layer] + s * wk;
            let vo = self.v_base[layer] + s * wv;
            k_out.extend_from_slice(&self.k_pages[page][ko..ko + take * wk]);
            v_out.extend_from_slice(&self.v_pages[page][vo..vo + take * wv]);
            pos += take;
        }
    }
}

/// Disjoint mutable views of pages `src` and `dst` (for the CoW copy).
fn two_pages(pages: &mut [Vec<f32>], src: usize, dst: usize) -> (&[f32], &mut [f32]) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (a, b) = pages.split_at_mut(dst);
        (&a[src], &mut b[0])
    } else {
        let (a, b) = pages.split_at_mut(src);
        (&b[0], &mut a[dst])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kvc::KvProj;

    fn cfg() -> ModelConfig {
        let mut cfg = ModelConfig::builtin("llama-t").unwrap();
        cfg.n_layers = 2;
        cfg
    }

    fn row(d: usize, fill: f32) -> Vec<f32> {
        (0..d).map(|i| fill + i as f32).collect()
    }

    /// Write position `pos` of `seq` across both layers (prepare + push).
    fn write_pos(pool: &mut KvPool, seq: SeqId, pos: usize, fill: f32, d: usize) {
        pool.prepare(seq, pos).expect("page available");
        let k = row(d, fill);
        let v = row(d, -fill);
        for layer in 0..2 {
            pool.push_row(seq, layer, pos, &k, &v);
        }
        pool.set_len(seq, pool.len(seq).max(pos + 1));
    }

    #[test]
    fn serve_pool_pages_fault_in_on_demand() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 4, 2);
        assert_eq!(pool.free_pages(), 4);
        let s = pool.new_seq();
        // Admission claims nothing; the first write faults in page 0.
        assert_eq!(pool.free_pages(), 4);
        write_pos(&mut pool, s, 0, 1.0, d);
        assert_eq!(pool.free_pages(), 3);
        write_pos(&mut pool, s, 1, 2.0, d);
        assert_eq!(pool.free_pages(), 3, "position 1 fits the first page");
        write_pos(&mut pool, s, 2, 3.0, d);
        assert_eq!(pool.free_pages(), 2, "position 2 opens the second page");
        assert_eq!(pool.len(s), 3);
        let (k, _v) = pool.hist_slices(s, 0, 2, 3).expect("one-page span");
        assert_eq!(k, &row(d, 3.0)[..]);
    }

    #[test]
    fn serve_pool_exhaustion_returns_none_without_corruption() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 1, 2);
        let s = pool.new_seq();
        write_pos(&mut pool, s, 0, 1.0, d);
        write_pos(&mut pool, s, 1, 2.0, d);
        // Third position needs a second page: the pool is out.
        assert!(pool.prepare(s, 2).is_none());
        // The failed fault must leave the table untouched and the stored
        // history readable.
        assert_eq!(pool.seq_pages(s), 1);
        assert_eq!(pool.len(s), 2);
        let mut k = Vec::new();
        let mut v = Vec::new();
        pool.gather_hist(s, 1, 0, 2, &mut k, &mut v);
        assert_eq!(&k[..d], &row(d, 1.0)[..]);
        assert_eq!(&k[d..], &row(d, 2.0)[..]);
        assert_eq!(&v[..d], &row(d, -1.0)[..]);
        // Releasing recovers the page.
        pool.release_seq(s);
        assert_eq!(pool.free_pages(), 1);
    }

    #[test]
    fn serve_pool_cow_copies_exactly_once() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 4, 2);
        let a = pool.new_seq();
        write_pos(&mut pool, a, 0, 1.0, d);
        write_pos(&mut pool, a, 1, 2.0, d);
        let shared_page = pool.page_at(a, 0);
        // B forks over A's first page.
        let b = pool.fork_seq(&[shared_page]);
        assert_eq!(pool.page_refs(shared_page), 2);
        assert_eq!(pool.len(b), 2);
        assert!(pool.seq_is_shared(a));
        let free_before = pool.free_pages();
        // B rewrites position 1 → CoW: exactly one page claimed, A's copy
        // untouched.
        pool.prepare(b, 1).unwrap();
        assert_eq!(pool.free_pages(), free_before - 1, "CoW claims one page");
        assert_ne!(pool.page_at(b, 0), shared_page);
        assert_eq!(pool.page_refs(shared_page), 1);
        let k9 = row(d, 9.0);
        for layer in 0..2 {
            pool.push_row(b, layer, 1, &k9, &k9);
        }
        // Second write to the now-unique page claims nothing further.
        pool.prepare(b, 0).unwrap();
        assert_eq!(pool.free_pages(), free_before - 1, "CoW copies exactly once");
        // A's history is unchanged; B sees its own write, plus the copied
        // position 0 from before the fork.
        let (ka, _) = pool.hist_slices(a, 0, 0, 2).unwrap();
        assert_eq!(&ka[d..], &row(d, 2.0)[..]);
        let (kb, _) = pool.hist_slices(b, 0, 0, 2).unwrap();
        assert_eq!(&kb[..d], &row(d, 1.0)[..], "CoW preserved pre-fork rows");
        assert_eq!(&kb[d..], &k9[..]);
    }

    #[test]
    fn serve_pool_refcounts_round_trip_free_count() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 6, 2);
        let total = pool.free_pages();
        // Full admit/extend/share/retire cycle must return every page.
        let a = pool.new_seq();
        for pos in 0..5 {
            write_pos(&mut pool, a, pos, pos as f32, d);
        }
        let p0 = pool.page_at(a, 0);
        let b = pool.fork_seq(&[p0]);
        pool.ref_page(p0); // a trie-style third reference
        pool.release_seq(a);
        assert!(pool.free_pages() < total, "shared + trie refs keep pages");
        pool.release_seq(b);
        assert_eq!(pool.page_refs(p0), 1, "trie ref still pins page 0");
        assert!(pool.unref_page(p0));
        assert_eq!(pool.free_pages(), total, "free count round-trips");
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn serve_pool_set_len_truncation_releases_tail_pages() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 4, 2);
        let s = pool.new_seq();
        for pos in 0..7 {
            write_pos(&mut pool, s, pos, pos as f32, d);
        }
        assert_eq!(pool.seq_pages(s), 4);
        assert_eq!(pool.free_pages(), 0);
        // Truncate to 3 positions: pages 2 and 3 (positions 4..8) release,
        // page 1 stays (position 2..4 partially valid).
        pool.set_len(s, 3);
        assert_eq!(pool.len(s), 3);
        assert_eq!(pool.seq_pages(s), 2);
        assert_eq!(pool.free_pages(), 2);
        // The surviving rows are intact and regrowth works.
        let (k, _) = pool.hist_slices(s, 0, 2, 3).unwrap();
        assert_eq!(k, &row(d, 2.0)[..]);
        write_pos(&mut pool, s, 3, 30.0, d);
        assert_eq!(pool.seq_pages(s), 2, "position 3 reuses the partial page");
    }

    #[test]
    fn serve_pool_seq_handles_recycle() {
        let cfg = cfg();
        let mut pool = KvPool::new(&cfg, 2, 4);
        let a = pool.new_seq();
        let b = pool.new_seq();
        assert_ne!(a, b);
        pool.release_seq(a);
        let c = pool.new_seq();
        assert_eq!(c, a, "slab handle recycles LIFO");
        assert_eq!(pool.len(c), 0);
        assert_eq!(pool.live_seqs(), 2);
    }

    #[test]
    fn serve_pool_gather_crosses_pages_and_matches_slices() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 4, 2);
        let s = pool.new_seq();
        for pos in 0..6 {
            write_pos(&mut pool, s, pos, 10.0 * pos as f32, d);
        }
        // Cross-page span has no borrow fast path.
        assert!(pool.hist_slices(s, 0, 0, 3).is_none());
        let mut k = Vec::new();
        let mut v = Vec::new();
        pool.gather_hist(s, 1, 2, 6, &mut k, &mut v);
        assert_eq!(k.len(), 4 * d);
        for (i, pos) in (2..6).enumerate() {
            assert_eq!(&k[i * d..(i + 1) * d], &row(d, 10.0 * pos as f32)[..]);
            assert_eq!(&v[i * d..(i + 1) * d], &row(d, -10.0 * pos as f32)[..]);
        }
    }

    #[test]
    #[should_panic(expected = "without prepare")]
    fn serve_pool_rejects_unprepared_write() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 1, 2);
        let s = pool.new_seq();
        let r = row(d, 0.0);
        pool.push_row(s, 0, 0, &r, &r);
    }

    /// A KvCompression with every layer's K and V at latent rank `r`
    /// (identity-shaped factors — pool tests care about widths, not math).
    fn uniform_kvc(layers: usize, d: usize, r: usize) -> KvCompression {
        let mut kvc = KvCompression::identity(layers);
        for l in 0..layers {
            kvc.layers[l].k = Some(KvProj::new(d, r, d, vec![0.0; d * r], vec![0.0; r * d]));
            kvc.layers[l].v = Some(KvProj::new(d, r, d, vec![0.0; d * r], vec![0.0; r * d]));
        }
        kvc
    }

    /// Admit fixed-length sequences until the free list runs dry; each
    /// needs `ceil(len/page_size)` pages.
    fn admit_until_full(pool: &mut KvPool, seq_len: usize) -> usize {
        let mut admitted = 0usize;
        loop {
            let s = pool.new_seq();
            for pos in 0..seq_len {
                if pool.prepare(s, pos).is_none() {
                    pool.release_seq(s);
                    return admitted;
                }
                pool.set_len(s, pos + 1);
            }
            admitted += 1;
        }
    }

    /// Satellite regression: at kv-ratio r/d = 1/4 the SAME byte budget
    /// admits ≥ 4× the sequences before first exhaustion, and the
    /// page-byte accounting agrees with the actual backing allocations.
    #[test]
    fn kv_compress_pool_admits_more_sequences_at_equal_bytes() {
        let cfg = cfg();
        let d = cfg.d_model;
        let (page_size, seq_len) = (4usize, 8usize);
        let dense_pages = 6usize;
        let dense = KvPool::new(&cfg, dense_pages, page_size);
        let budget = dense_pages * dense.page_bytes();
        let kvc = uniform_kvc(cfg.n_layers, d, d / 4);
        // Same byte budget, quarter-width rows → 4× the page count.
        let probe = KvPool::with_kvc(&cfg, 1, page_size, Some(&kvc));
        let compressed_pages = budget / probe.page_bytes();
        assert_eq!(compressed_pages, 4 * dense_pages);
        let mut dense = dense;
        let mut compressed = KvPool::with_kvc(&cfg, compressed_pages, page_size, Some(&kvc));
        let base = admit_until_full(&mut dense, seq_len);
        let more = admit_until_full(&mut compressed, seq_len);
        assert!(base > 0);
        assert!(
            more >= 4 * base,
            "equal-memory admission: {more} compressed vs {base} dense (need ≥ 4×)"
        );
        // Accounting agrees with the real allocations, both dtypes.
        for pool in [&dense, &compressed] {
            let actual: usize = pool
                .k_pages
                .iter()
                .chain(pool.v_pages.iter())
                .map(|p| 4 * p.len())
                .sum();
            assert_eq!(pool.page_bytes() * pool.pages(), actual);
        }
        for l in 0..cfg.n_layers {
            assert_eq!(compressed.width_k(l), d / 4);
            assert_eq!(compressed.width_v(l), d / 4);
            assert_eq!(dense.width_k(l), d);
        }
    }

    /// Mixed per-layer widths: layer 0 compressed (K only), layer 1 dense.
    /// Rows land at their layer's base offsets and round-trip intact.
    #[test]
    fn kv_compress_pool_mixed_widths_round_trip() {
        let cfg = cfg();
        let d = cfg.d_model;
        let r = d / 2;
        let mut kvc = KvCompression::identity(cfg.n_layers);
        kvc.layers[0].k = Some(KvProj::new(d, r, d, vec![0.0; d * r], vec![0.0; r * d]));
        let mut pool = KvPool::with_kvc(&cfg, 2, 2, Some(&kvc));
        assert_eq!(pool.width_k(0), r);
        assert_eq!(pool.width_v(0), d);
        assert_eq!(pool.width_k(1), d);
        assert_eq!(pool.page_bytes(), 4 * 2 * (r + 3 * d));
        let s = pool.new_seq();
        for pos in 0..3 {
            pool.prepare(s, pos).unwrap();
            let fill = 10.0 * pos as f32;
            pool.push_row(s, 0, pos, &row(r, fill), &row(d, -fill));
            pool.push_row(s, 1, pos, &row(d, fill + 1.0), &row(d, -fill - 1.0));
            pool.set_len(s, pos + 1);
        }
        // Single-page span widths follow the layer.
        let (k0, v0) = pool.hist_slices(s, 0, 2, 3).unwrap();
        assert_eq!(k0, &row(r, 20.0)[..]);
        assert_eq!(v0, &row(d, -20.0)[..]);
        let (k1, _) = pool.hist_slices(s, 1, 2, 3).unwrap();
        assert_eq!(k1, &row(d, 21.0)[..]);
        // Cross-page gather keeps per-layer stride.
        let mut k = Vec::new();
        let mut v = Vec::new();
        pool.gather_hist(s, 0, 0, 3, &mut k, &mut v);
        assert_eq!(k.len(), 3 * r);
        assert_eq!(v.len(), 3 * d);
        assert_eq!(&k[r..2 * r], &row(r, 10.0)[..]);
        assert_eq!(&v[d..2 * d], &row(d, -10.0)[..]);
    }
}
