//! Prompt-prefix sharing: a radix trie over token-id chunks that maps common
//! prompt prefixes onto already-populated KV pages.
//!
//! Sharing is sound because the KV rows at position `p` are a deterministic
//! function of token ids `0..=p` — both paths (sequential `generate` and the
//! batched serve step) compute them through the same per-row helpers in the
//! same float-op order.  So when two prompts agree on their first
//! `k · page_size` tokens, the second request can alias the first request's
//! first `k` pages verbatim ([`super::kv_pool::KvPool::fork_seq`]) and skip
//! prefilling those positions entirely.
//!
//! Granularity is one trie node per **full** page: a node stores the exact
//! `page_size` token ids covering its page, so lookup is exact-match chunk
//! by chunk (never a partial page — a partially filled page is still being
//! written by its owner and must not be aliased).  Each registered node
//! holds one pool reference on its page; sequences forked over it hold their
//! own, so evicting a trie entry never invalidates a live request's history.
//!
//! The trie lives on the scheduler thread next to the pool — same
//! single-thread, between-steps mutation discipline, no locks.

use super::kv_pool::{KvPool, PageId};

/// Sentinel: the root node (empty prefix, no page).
pub const ROOT: usize = 0;
const NO_PAGE: PageId = usize::MAX;

#[derive(Debug)]
struct Node {
    /// The `page_size` token ids this node's page covers.
    chunk: Vec<u8>,
    page: PageId,
    parent: usize,
    children: Vec<usize>,
    /// Monotone LRU stamp, bumped on every lookup hit and registration.
    last_use: u64,
    live: bool,
}

/// Radix trie over `page_size`-token chunks; values are pool page ids.
#[derive(Debug)]
pub struct PrefixTrie {
    page_size: usize,
    nodes: Vec<Node>,
    /// Dead node slots for reuse.
    free: Vec<usize>,
    clock: u64,
    /// Registered (live, non-root) entries.
    entries: usize,
    /// Lookup accounting for the serve metrics: positions served from the
    /// trie vs. prompt positions that had to be prefilled.
    pub hit_positions: u64,
    pub miss_positions: u64,
}

impl PrefixTrie {
    pub fn new(page_size: usize) -> PrefixTrie {
        assert!(page_size > 0);
        PrefixTrie {
            page_size,
            nodes: vec![Node {
                chunk: Vec::new(),
                page: NO_PAGE,
                parent: ROOT,
                children: Vec::new(),
                last_use: 0,
                live: true,
            }],
            free: Vec::new(),
            clock: 0,
            entries: 0,
            hit_positions: 0,
            miss_positions: 0,
        }
    }

    /// Live registered entries (== pool pages the trie holds a ref on).
    pub fn entries(&self) -> usize {
        self.entries
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest registered prefix of `prompt`, as the chain of matched
    /// `(node, page)` pairs (only full chunks: `len · page_size ≤
    /// prompt.len()`).  Bumps LRU stamps along the match and records
    /// hit/miss position counts.
    pub fn lookup(&mut self, prompt: &[u8]) -> Vec<(usize, PageId)> {
        let stamp = self.tick();
        let mut at = ROOT;
        let mut chain = Vec::new();
        let mut off = 0;
        while off + self.page_size <= prompt.len() {
            let want = &prompt[off..off + self.page_size];
            let next = self.nodes[at]
                .children
                .iter()
                .copied()
                .find(|&c| self.nodes[c].chunk == want);
            match next {
                Some(c) => {
                    self.nodes[c].last_use = stamp;
                    chain.push((c, self.nodes[c].page));
                    at = c;
                    off += self.page_size;
                }
                None => break,
            }
        }
        self.hit_positions += off as u64;
        self.miss_positions += (prompt.len() - off) as u64;
        chain
    }

    /// Register `chunk` (exactly `page_size` tokens) under `parent` as
    /// mapping to `page`, taking one pool reference on it.  If an identical
    /// child already exists (two same-prefix requests prefilled in the same
    /// step), the existing node is returned and no reference is taken.
    /// Returns the node id to use as the next chunk's parent.
    pub fn register(&mut self, pool: &mut KvPool, parent: usize, chunk: &[u8], page: PageId) -> usize {
        assert_eq!(chunk.len(), self.page_size, "only full pages are shareable");
        debug_assert!(self.nodes[parent].live);
        let stamp = self.tick();
        if let Some(c) = self.nodes[parent]
            .children
            .iter()
            .copied()
            .find(|&c| self.nodes[c].chunk == chunk)
        {
            self.nodes[c].last_use = stamp;
            return c;
        }
        pool.ref_page(page);
        let node = Node {
            chunk: chunk.to_vec(),
            page,
            parent,
            children: Vec::new(),
            last_use: stamp,
            live: true,
        };
        let id = match self.free.pop() {
            Some(id) => {
                self.nodes[id] = node;
                id
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.nodes[parent].children.push(id);
        self.entries += 1;
        id
    }

    /// Drop the least-recently-used **leaf** entry, returning its page
    /// reference to the pool (the page itself is freed only if no sequence
    /// still aliases it).  Leaves only: an inner node is the lookup path to
    /// its descendants.  `pinned` nodes are skipped — the batcher pins the
    /// registration tail of each active still mid-prompt, because evicting
    /// a tail would let its slot be recycled and a later registration would
    /// chain chunks under the wrong parent.  Returns `true` when an entry
    /// was evicted — the caller loops `evict_lru` + retry while the pool
    /// stays exhausted.
    pub fn evict_lru(&mut self, pool: &mut KvPool, pinned: &[usize]) -> bool {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(id, n)| {
                *id != ROOT && n.live && n.children.is_empty() && !pinned.contains(id)
            })
            .min_by_key(|(_, n)| n.last_use)
            .map(|(id, _)| id);
        let Some(id) = victim else {
            return false;
        };
        let parent = self.nodes[id].parent;
        self.nodes[parent].children.retain(|&c| c != id);
        let page = self.nodes[id].page;
        self.nodes[id].live = false;
        self.nodes[id].chunk = Vec::new();
        self.free.push(id);
        self.entries -= 1;
        pool.unref_page(page);
        true
    }

    /// Drop every entry (server shutdown), releasing all held page refs.
    pub fn clear(&mut self, pool: &mut KvPool) {
        while self.evict_lru(pool, &[]) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn pool(pages: usize, page_size: usize) -> KvPool {
        let mut cfg = ModelConfig::builtin("llama-t").unwrap();
        cfg.n_layers = 2;
        KvPool::new(&cfg, pages, page_size)
    }

    /// Admit a sequence and fill `n` positions so pages exist to register.
    fn fill_seq(pool: &mut KvPool, n: usize) -> usize {
        let d = {
            let mut cfg = ModelConfig::builtin("llama-t").unwrap();
            cfg.n_layers = 2;
            cfg.d_model
        };
        let s = pool.new_seq();
        let row = vec![0.5f32; d];
        for pos in 0..n {
            pool.prepare(s, pos).unwrap();
            for layer in 0..2 {
                pool.push_row(s, layer, pos, &row, &row);
            }
            pool.set_len(s, pos + 1);
        }
        s
    }

    #[test]
    fn serve_trie_lookup_matches_longest_registered_prefix() {
        let mut pool = pool(8, 4);
        let mut trie = PrefixTrie::new(4);
        let s = fill_seq(&mut pool, 8);
        let prompt: Vec<u8> = (0..12).collect();
        let n0 = trie.register(&mut pool, ROOT, &prompt[0..4], pool.page_at(s, 0));
        trie.register(&mut pool, n0, &prompt[4..8], pool.page_at(s, 1));
        assert_eq!(trie.entries(), 2);
        // Full two-chunk match; the 12th..-token tail is a miss.
        let chain = trie.lookup(&prompt);
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[0].1, pool.page_at(s, 0));
        assert_eq!(chain[1].1, pool.page_at(s, 1));
        assert_eq!(trie.hit_positions, 8);
        assert_eq!(trie.miss_positions, 4);
        // Diverging second chunk matches only the first.
        let mut other = prompt.clone();
        other[5] ^= 0xFF;
        assert_eq!(trie.lookup(&other).len(), 1);
        // A prompt shorter than one page can never match.
        assert!(trie.lookup(&prompt[..3]).is_empty());
    }

    #[test]
    fn serve_trie_register_is_idempotent_per_chunk() {
        let mut pool = pool(8, 4);
        let mut trie = PrefixTrie::new(4);
        let s = fill_seq(&mut pool, 4);
        let page = pool.page_at(s, 0);
        let chunk: Vec<u8> = vec![7; 4];
        let a = trie.register(&mut pool, ROOT, &chunk, page);
        assert_eq!(pool.page_refs(page), 2, "seq + trie");
        let b = trie.register(&mut pool, ROOT, &chunk, page);
        assert_eq!(a, b, "duplicate registration returns the existing node");
        assert_eq!(pool.page_refs(page), 2, "no double reference");
        assert_eq!(trie.entries(), 1);
    }

    #[test]
    fn serve_trie_eviction_is_lru_leaves_first() {
        let mut pool = pool(8, 2);
        let mut trie = PrefixTrie::new(2);
        let s = fill_seq(&mut pool, 6);
        let pages: Vec<PageId> = (0..3).map(|i| pool.page_at(s, i)).collect();
        // Chain a→b plus sibling c; then touch a+b via lookup so c is LRU.
        let a = trie.register(&mut pool, ROOT, &[0, 1], pages[0]);
        let b = trie.register(&mut pool, a, &[2, 3], pages[1]);
        trie.register(&mut pool, ROOT, &[9, 9], pages[2]);
        trie.lookup(&[0, 1, 2, 3]);
        assert!(trie.evict_lru(&mut pool, &[]));
        assert_eq!(trie.entries(), 2);
        assert_eq!(pool.page_refs(pages[2]), 1, "sibling c evicted first");
        // A pinned leaf is skipped: with b pinned, nothing is evictable
        // (a is an inner node).
        assert!(!trie.evict_lru(&mut pool, &[b]));
        // Next unpinned eviction takes the leaf b, not the inner node a.
        assert!(trie.evict_lru(&mut pool, &[]));
        assert_eq!(pool.page_refs(pages[1]), 1);
        assert_eq!(pool.page_refs(pages[0]), 2, "inner node a survives as leaf-to-be");
        assert!(trie.evict_lru(&mut pool, &[]));
        assert!(!trie.evict_lru(&mut pool, &[]), "empty trie has nothing to evict");
        // The trie's refs are gone; the sequence still owns its pages.
        pool.release_seq(s);
        assert_eq!(pool.free_pages(), 8);
    }

    #[test]
    fn serve_trie_eviction_keeps_shared_pages_alive_for_sequences() {
        let mut pool = pool(4, 2);
        let mut trie = PrefixTrie::new(2);
        let s = fill_seq(&mut pool, 2);
        let page = pool.page_at(s, 0);
        trie.register(&mut pool, ROOT, &[0, 1], page);
        // A second request forks over the shared page via lookup.
        let chain = trie.lookup(&[0, 1, 5, 6]);
        let forked = pool.fork_seq(&[chain[0].1]);
        assert_eq!(pool.page_refs(page), 3);
        // Evicting the trie entry must not free the page under the fork.
        trie.clear(&mut pool);
        assert_eq!(pool.page_refs(page), 2);
        assert_eq!(pool.len(forked), 2);
        pool.release_seq(forked);
        pool.release_seq(s);
        assert_eq!(pool.free_pages(), 4);
    }
}
