//! Per-request streaming token delivery.
//!
//! Each generation request carries its own [`TokenStream`] sender; the
//! scheduler pushes every sampled token into it the moment the step that
//! produced it finishes, so clients see tokens with per-step latency
//! instead of per-request latency.  The channel doubles as the
//! cancellation signal: when the client drops its receiver, the next
//! *token* send fails and the batcher retires the sequence and returns
//! its KV pages to the pool.  (mpsc reports disconnection only on send and prefill
//! steps send nothing, so a request cancelled mid-prompt is detected at
//! its first generated token — prefill of a dead request still runs,
//! bounded by the prompt length.)

use std::sync::mpsc::{channel, Receiver, Sender};

/// Why a request left the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full `max_new` tokens.
    Completed,
    /// The client dropped its stream receiver mid-generation.
    Cancelled,
    /// Refused at admission (empty prompt, `max_new == 0`, the
    /// `⌈(prompt + max_new - 1) / page_size⌉` KV pages the request could
    /// need exceeding the entire pool, or arriving at a full bounded queue
    /// while being the least-urgent work the server knows about).
    Rejected,
    /// Dropped by the overload policy: the bounded admission queue was
    /// full and a *more urgent* arrival displaced this request (which may
    /// already have been queued, preempted, or even running — any tokens
    /// streamed before the shed are still a bit-exact prefix of the
    /// sequential `generate` output).
    Shed,
    /// Killed by the scheduler because its deadline expired before it
    /// completed (whether still queued, preempted, or actively decoding).
    DeadlineExceeded,
    /// Retired by the watchdog: a panic or injected fault occurred inside
    /// this request's step rows.  Only this request dies — neighbors in
    /// the same batch re-execute bit-identically and the server survives.
    Faulted,
}

impl FinishReason {
    /// Stable lower-snake label used in trace events, metric names, and
    /// log lines (`serve.requests.<label>` counters).
    pub fn label(self) -> &'static str {
        match self {
            FinishReason::Completed => "completed",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Rejected => "rejected",
            FinishReason::Shed => "shed",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
            FinishReason::Faulted => "faulted",
        }
    }
}

/// Final per-request summary, sent after the last token.
#[derive(Clone, Debug)]
pub struct DoneStats {
    /// The request's id (echoed from [`super::batcher::GenRequest`]).
    pub id: u64,
    /// Tokens actually generated (sampled — including any the client
    /// never saw because it hung up).
    pub generated: usize,
    /// Why the request finished.
    pub finish: FinishReason,
    /// Enqueue → finish, seconds.
    pub latency_s: f64,
    /// Enqueue → first generated token, seconds (equals `latency_s` when
    /// no token was produced).
    pub ttft_s: f64,
}

/// Events delivered over a request's stream channel.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// One generated token: `index` is 0-based within this request's
    /// output, `byte` the sampled token.
    Token {
        /// 0-based output index of this token.
        index: usize,
        /// The sampled token (byte-level vocab).
        byte: u8,
    },
    /// Generation finished — always the stream's last event.
    Done(DoneStats),
}

/// The server-side half of a request's stream.
#[derive(Clone, Debug)]
pub struct TokenStream {
    tx: Sender<StreamEvent>,
}

impl TokenStream {
    /// Deliver an event; `false` means the client hung up (the batcher
    /// treats that as cancellation).
    pub fn send(&self, event: StreamEvent) -> bool {
        self.tx.send(event).is_ok()
    }
}

/// Create a request's stream pair: the [`TokenStream`] travels to the
/// server inside the request, the receiver stays with the client.
pub fn stream_channel() -> (TokenStream, Receiver<StreamEvent>) {
    let (tx, rx) = channel();
    (TokenStream { tx }, rx)
}

/// Drain a stream to completion: blocks until [`StreamEvent::Done`] (or
/// the server dropped the sender) and returns the tokens in order plus the
/// final stats.
pub fn collect_stream(rx: &Receiver<StreamEvent>) -> (Vec<u8>, Option<DoneStats>) {
    let mut tokens = Vec::new();
    let mut done = None;
    for event in rx.iter() {
        match event {
            StreamEvent::Token { byte, .. } => tokens.push(byte),
            StreamEvent::Done(stats) => {
                done = Some(stats);
                break;
            }
        }
    }
    (tokens, done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_stream_collects_tokens_then_done() {
        let (tx, rx) = stream_channel();
        assert!(tx.send(StreamEvent::Token { index: 0, byte: 7 }));
        assert!(tx.send(StreamEvent::Token { index: 1, byte: 9 }));
        assert!(tx.send(StreamEvent::Done(DoneStats {
            id: 3,
            generated: 2,
            finish: FinishReason::Completed,
            latency_s: 0.5,
            ttft_s: 0.1,
        })));
        let (tokens, done) = collect_stream(&rx);
        assert_eq!(tokens, vec![7, 9]);
        let done = done.unwrap();
        assert_eq!(done.id, 3);
        assert_eq!(done.finish, FinishReason::Completed);
    }

    #[test]
    fn serve_stream_detects_hangup() {
        let (tx, rx) = stream_channel();
        drop(rx);
        assert!(!tx.send(StreamEvent::Token { index: 0, byte: 1 }));
    }

    #[test]
    fn serve_stream_collect_survives_dropped_sender() {
        let (tx, rx) = stream_channel();
        assert!(tx.send(StreamEvent::Token { index: 0, byte: 4 }));
        drop(tx);
        let (tokens, done) = collect_stream(&rx);
        assert_eq!(tokens, vec![4]);
        assert!(done.is_none());
    }
}
