//! Seeded, deterministic fault injection for the generation server.
//!
//! Chaos decisions are **stateless**: each verdict is a pure hash of
//! `(seed, fault kind, step number, request id)`, so the scheduler can ask
//! the same question twice — once for the batched step attempt and again
//! inside the watchdog's per-request isolation re-run — and get the same
//! answer.  That stability is what lets the chaos grid assert exact
//! outcomes: a request either faults at a given step or it does not,
//! independent of which neighbors shared its batch or how the fallback
//! partitioned the rows.
//!
//! Three fault families are modeled:
//!
//! * **Step faults** (`step_fault_rate`) — the request's rows "die" during
//!   the batched step: the batcher surfaces this as a step error, the
//!   watchdog isolates it, and the request retires with
//!   [`crate::serve::stream::FinishReason::Faulted`].  Genuine panics take
//!   the identical path (see the watchdog in `batcher`).
//! * **Allocation faults** (`alloc_fail_rate`) — the first KV-page
//!   `prepare()` a sequence issues in a step reports pool exhaustion even
//!   if pages are free, driving the real recovery ladder (trie eviction →
//!   preemption → short chunk).  The retry hits the true pool, so these
//!   faults are transient and **never** change a surviving request's
//!   output bits — only its schedule.
//! * **Stalled / slow client streams** — modeled harness-side in the chaos
//!   grid (`serve/fuzz.rs`): client threads sleep or hang up mid-stream,
//!   exercising the cancellation path; no server hook is needed because
//!   cancellation is already detected at the token send.

/// Deterministic fault-injection configuration, carried in
/// [`crate::serve::GenConfig::chaos`].  `Default` (all rates zero)
/// injects nothing.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChaosConfig {
    /// Seed for every injection decision; two servers with equal seeds and
    /// rates inject identical faults at identical `(step, request)` points.
    pub seed: u64,
    /// Probability that a request's step rows fail in a given step.
    pub step_fault_rate: f64,
    /// Probability that a sequence's first page allocation in a given step
    /// is refused (transient — the retry uses the real pool).
    pub alloc_fail_rate: f64,
}

/// Domain-separation salts so the two fault families draw independent
/// verdicts from the same seed.
const SALT_STEP: u64 = 0x5345_5256_4552_0001;
const SALT_ALLOC: u64 = 0x5345_5256_4552_0002;

/// One round of splitmix64 — mixes a 64-bit state into a well-distributed
/// output (same finalizer the crate's [`crate::util::rng::Rng`] uses).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Hash `(seed, salt, step, id)` to a uniform f64 in `[0, 1)`.
fn uniform(seed: u64, salt: u64, step: u64, id: u64) -> f64 {
    let mut h = splitmix64(seed ^ salt);
    h = splitmix64(h ^ step.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    h = splitmix64(h ^ id);
    // Top 53 bits → [0, 1) with full double precision.
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl ChaosConfig {
    /// `true` when any fault family can fire; the batcher skips all chaos
    /// bookkeeping otherwise.
    pub fn is_active(&self) -> bool {
        self.step_fault_rate > 0.0 || self.alloc_fail_rate > 0.0
    }

    /// Should request `id`'s rows fail during step `step`?  Pure — the
    /// batched attempt and the watchdog re-run see the same verdict.
    pub fn step_fault(&self, step: u64, id: u64) -> bool {
        self.step_fault_rate > 0.0 && uniform(self.seed, SALT_STEP, step, id) < self.step_fault_rate
    }

    /// Should request `id`'s first page allocation in step `step` be
    /// refused?  At most one refusal per `(step, request)` — the batcher
    /// gives the fault a budget of one so recovery is exercised without
    /// livelock.
    pub fn alloc_fault(&self, step: u64, id: u64) -> bool {
        self.alloc_fail_rate > 0.0 && uniform(self.seed, SALT_ALLOC, step, id) < self.alloc_fail_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_decisions_are_deterministic_and_stateless() {
        let c = ChaosConfig { seed: 42, step_fault_rate: 0.3, alloc_fail_rate: 0.3 };
        for step in 0..64 {
            for id in 0..16 {
                assert_eq!(c.step_fault(step, id), c.step_fault(step, id));
                assert_eq!(c.alloc_fault(step, id), c.alloc_fault(step, id));
            }
        }
    }

    #[test]
    fn chaos_rates_bound_empirical_frequency() {
        for &rate in &[0.0, 0.05, 0.2, 1.0] {
            let c = ChaosConfig { seed: 7, step_fault_rate: rate, alloc_fail_rate: rate };
            let n = 20_000u64;
            let hits = (0..n).filter(|&i| c.step_fault(i / 100, i % 100)).count() as f64;
            let freq = hits / n as f64;
            assert!(
                (freq - rate).abs() < 0.02,
                "rate {rate}: empirical {freq}"
            );
            if rate == 0.0 {
                assert!(!c.is_active() || c.alloc_fail_rate > 0.0);
            }
        }
    }

    #[test]
    fn chaos_families_draw_independent_verdicts() {
        let c = ChaosConfig { seed: 9, step_fault_rate: 0.5, alloc_fail_rate: 0.5 };
        // Same (step, id) grid; the two salts must not produce identical
        // verdict sequences.
        let agree = (0..1000u64)
            .filter(|&i| c.step_fault(i, 0) == c.alloc_fault(i, 0))
            .count();
        assert!(agree > 300 && agree < 700, "agreement {agree}/1000");
    }

    #[test]
    fn chaos_default_is_inert() {
        let c = ChaosConfig::default();
        assert!(!c.is_active());
        assert!(!c.step_fault(0, 0));
        assert!(!c.alloc_fault(0, 0));
    }
}
