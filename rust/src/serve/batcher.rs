//! The step-level scheduler: continuous batching over the slotted KV pool.
//!
//! One scheduler thread owns the [`KvPool`] and the decode loop; producers
//! fan [`GenRequest`]s in over an mpsc channel from any number of threads.
//! Between decode steps the scheduler (a) retires finished or cancelled
//! sequences, recycling their slots in O(1), and (b) admits queued
//! requests into free slots — a request admitted at step *t* starts
//! prefilling at step *t* while its neighbors keep decoding, and its
//! output is bit-identical to a fresh single-request run
//! ([`crate::model::generate::generate`]) because the batched step is
//! bit-identical per row and sampling state is per-request
//! (seeded [`Rng`] from the request's own [`SampleConfig::seed`]).

use super::kv_pool::KvPool;
use super::step::{decode_step_batched, StepRow};
use super::stream::{DoneStats, FinishReason, StreamEvent, TokenStream};
use crate::coordinator::metrics::GenServerMetrics;
use crate::model::config::ModelConfig;
use crate::model::forward::LinearOverride;
use crate::model::generate::{sample_token, SampleConfig};
use crate::model::weights::Weights;
use crate::util::rng::Rng;
use crate::util::threads::ThreadBudget;
use crate::util::timer::Timer;
use anyhow::Result;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::time::Instant;

/// One generation request.
#[derive(Debug)]
pub struct GenRequest {
    /// Caller-chosen id, echoed in [`DoneStats`].
    pub id: u64,
    /// Prompt tokens (must be non-empty).
    pub prompt: Vec<u8>,
    /// Tokens to generate (must be ≥ 1).
    pub max_new: usize,
    /// Per-request sampling configuration; `seed` makes the output
    /// deterministic regardless of co-batched neighbors.
    pub sample: SampleConfig,
    /// Streaming delivery channel back to the client.
    pub stream: TokenStream,
    /// When the client enqueued the request (for latency metrics).
    pub enqueued: Instant,
}

/// Generation-server knobs.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// Maximum sequences decoded per step (the GEMM row count cap).
    pub max_batch: usize,
    /// KV pool slot count (resident-sequence cap; a separate knob from
    /// `max_batch` for schedulers that admit more residents than they
    /// decode per step).  The current step scheduler decodes every
    /// resident each step, so it clamps this to `max_batch` — more slots
    /// would preallocate KV storage no sequence could occupy.
    pub slots: usize,
    /// Per-slot KV capacity: admission rejects requests needing more than
    /// `slot_cap` KV rows (`prompt + max_new - 1` — the final sampled
    /// token is never fed back).
    pub slot_cap: usize,
    /// Thread budget for the batched step's GEMMs (0 = all cores);
    /// bit-identical results at every value.
    pub workers: usize,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { max_batch: 8, slots: 8, slot_cap: 128, workers: 0 }
    }
}

/// One admitted sequence's scheduler state.
struct Active {
    req: GenRequest,
    slot: usize,
    rng: Rng,
    /// Position of the token fed next step.
    pos: usize,
    /// Token fed next step.
    token: u8,
    /// Tokens generated so far.
    produced: usize,
    /// Enqueue → first generated token, set once.
    ttft_s: Option<f64>,
}

/// Run the generation server until the request channel closes and every
/// admitted sequence has finished.  Blocks the calling thread (which
/// becomes the scheduler/owner of the pool); returns accumulated metrics.
pub fn serve_generation(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    gen: &GenConfig,
    requests: Receiver<GenRequest>,
) -> Result<GenServerMetrics> {
    let max_batch = gen.max_batch.max(1);
    // Admission caps at max_batch, so slots beyond it could never hold a
    // sequence — clamp rather than preallocate dead KV storage.
    let slots = gen.slots.max(1).min(max_batch);
    let slot_cap = gen.slot_cap.max(1);
    let step_workers = ThreadBudget::new(gen.workers).total();
    let mut pool = KvPool::new(cfg, slots, slot_cap);
    let mut active: Vec<Active> = Vec::new();
    let mut metrics = GenServerMetrics::default();
    let mut open = true;
    let wall = Timer::start();
    loop {
        // ---- admission: only between steps, never past free capacity ----
        while open && active.len() < max_batch && pool.free_count() > 0 {
            let next = if active.is_empty() {
                // Nothing in flight: block for work (or shutdown).
                match requests.recv() {
                    Ok(r) => Some(r),
                    Err(_) => {
                        open = false;
                        None
                    }
                }
            } else {
                match requests.try_recv() {
                    Ok(r) => Some(r),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            };
            let Some(req) = next else { break };
            // A request feeds prompt + max_new - 1 positions (the final
            // sampled token is never fed back), so that is the KV rows it
            // needs.
            if req.prompt.is_empty()
                || req.max_new == 0
                || req.prompt.len() + req.max_new - 1 > pool.cap()
            {
                let latency = req.enqueued.elapsed().as_secs_f64();
                let _ = req.stream.send(StreamEvent::Done(DoneStats {
                    id: req.id,
                    generated: 0,
                    finish: FinishReason::Rejected,
                    latency_s: latency,
                    ttft_s: latency,
                }));
                metrics.rejected += 1;
                continue;
            }
            let slot = pool.acquire().expect("free slot checked above");
            let rng = Rng::new(req.sample.seed);
            let token = req.prompt[0];
            active.push(Active { req, slot, rng, pos: 0, token, produced: 0, ttft_s: None });
        }
        if active.is_empty() {
            if !open {
                break;
            }
            continue; // back to the blocking recv
        }
        // ---- one batched decode step over every active sequence ----
        let rows: Vec<StepRow> = active
            .iter()
            .map(|a| StepRow {
                slot: a.slot,
                token: a.token,
                pos: a.pos,
                // Prefill rows (all but the last prompt token) never have
                // their logits read — the step skips their lm_head rows.
                needs_logits: a.pos + 1 >= a.req.prompt.len(),
            })
            .collect();
        let step_t = Timer::start();
        let logits = decode_step_batched(cfg, weights, overrides, &mut pool, &rows, step_workers)?;
        metrics.record_step(step_t.elapsed_s(), active.len() as f64);
        // ---- advance every row; collect finished ones ----
        let vocab = cfg.vocab;
        let mut finished: Vec<(usize, FinishReason)> = Vec::new();
        for (r, a) in active.iter_mut().enumerate() {
            a.pos += 1;
            if a.pos < a.req.prompt.len() {
                a.token = a.req.prompt[a.pos]; // still prefilling
                continue;
            }
            let row_logits = &logits[r * vocab..(r + 1) * vocab];
            let next = sample_token(row_logits, a.req.sample, &mut a.rng);
            let index = a.produced;
            a.produced += 1;
            metrics.generated += 1;
            if a.ttft_s.is_none() {
                a.ttft_s = Some(a.req.enqueued.elapsed().as_secs_f64());
            }
            let delivered = a.req.stream.send(StreamEvent::Token { index, byte: next });
            if !delivered {
                finished.push((r, FinishReason::Cancelled));
            } else if a.produced == a.req.max_new {
                finished.push((r, FinishReason::Completed));
            } else {
                a.token = next;
            }
        }
        // Retire in reverse index order so swap_remove never disturbs a
        // lower pending index; slots recycle in O(1).
        for (r, finish) in finished.into_iter().rev() {
            let a = active.swap_remove(r);
            pool.release(a.slot);
            let latency = a.req.enqueued.elapsed().as_secs_f64();
            let ttft = a.ttft_s.unwrap_or(latency);
            metrics.record_finish(latency, ttft);
            if finish == FinishReason::Cancelled {
                metrics.cancelled += 1;
            }
            let _ = a.req.stream.send(StreamEvent::Done(DoneStats {
                id: a.req.id,
                generated: a.produced,
                finish,
                latency_s: latency,
                ttft_s: ttft,
            }));
        }
    }
    metrics.wall_s = wall.elapsed_s();
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::NoOverride;
    use crate::model::generate::generate;
    use crate::serve::stream::collect_stream;
    use crate::util::prop::check;
    use std::sync::mpsc::channel;

    fn tiny(name: &str) -> (ModelConfig, Weights) {
        crate::serve::test_util::tiny(name, 47)
    }

    /// Preload `reqs`, serve to completion on this thread, return each
    /// request's streamed tokens (in request order) and the metrics —
    /// the shared harness from `crate::bench`.
    fn run_server(
        cfg: &ModelConfig,
        w: &Weights,
        gen: &GenConfig,
        reqs: Vec<(Vec<u8>, usize, SampleConfig)>,
    ) -> (Vec<Vec<u8>>, GenServerMetrics) {
        crate::bench::drive_preloaded(cfg, w, &NoOverride, gen, reqs)
    }

    fn reference(cfg: &ModelConfig, w: &Weights, reqs: &[(Vec<u8>, usize, SampleConfig)]) -> Vec<Vec<u8>> {
        reqs.iter()
            .map(|(prompt, max_new, sample)| {
                generate(cfg, w, &NoOverride, prompt, *max_new, *sample).unwrap()
            })
            .collect()
    }

    #[test]
    fn serve_matches_sequential_generate_all_families() {
        for name in ["llama-t", "opt-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..3)
                .map(|i| {
                    (
                        (0..(2 + i)).map(|t| ((t * 67 + i * 13) % 251) as u8).collect(),
                        4 + i,
                        SampleConfig { temperature: 0.9, top_k: 20, seed: 100 + i as u64 },
                    )
                })
                .collect();
            let expect = reference(&cfg, &w, &reqs);
            let gen = GenConfig { max_batch: 3, slots: 3, slot_cap: 16, workers: 1 };
            let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
            assert_eq!(got, expect, "{name}: served tokens must equal sequential generate");
            assert_eq!(metrics.completed, 3);
            assert_eq!(metrics.generated, 4 + 5 + 6);
        }
    }

    #[test]
    fn serve_bit_identical_across_batch_sizes_and_workers() {
        let (cfg, w) = tiny("llama-t");
        let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..8)
            .map(|i| {
                (
                    (0..(1 + i % 4)).map(|t| ((t * 41 + i * 7) % 256) as u8).collect(),
                    3 + i % 3,
                    SampleConfig { temperature: 0.8, top_k: 12, seed: i as u64 },
                )
            })
            .collect();
        let expect = reference(&cfg, &w, &reqs);
        // The FULL advertised grid: batch {1, 3, 8} × workers {1, 4}.
        for &max_batch in &[1usize, 3, 8] {
            for &workers in &[1usize, 4] {
                let gen = GenConfig { max_batch, slots: max_batch, slot_cap: 16, workers };
                let (got, metrics) = run_server(&cfg, &w, &gen, reqs.clone());
                assert_eq!(
                    got, expect,
                    "batch={max_batch} workers={workers}: output must be bit-identical"
                );
                assert!(metrics.batch_fill.iter().all(|&f| f <= max_batch as f64));
                assert_eq!(metrics.completed, 8);
            }
        }
    }

    /// Mid-stream join/leave: with fewer slots than requests, sequences
    /// join as slots free up at arbitrary steps t and must still match a
    /// fresh sequential run — across families, batch shapes, and workers.
    #[test]
    fn serve_mid_stream_join_leave_matches_sequential() {
        check("continuous-batching parity", 4, |g| {
            let name = *g.choose(&["llama-t", "opt-t", "mistral-t"]);
            let (cfg, w) = tiny(name);
            let n_req = g.usize_in(3, 6);
            let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..n_req)
                .map(|i| {
                    let plen = g.usize_in(1, 5);
                    let prompt = (0..plen).map(|_| g.usize_in(0, 256) as u8).collect();
                    let max_new = g.usize_in(1, 6);
                    let sample = SampleConfig {
                        temperature: 1.0,
                        top_k: 8,
                        seed: g.rng.next_u64(),
                    };
                    (prompt, max_new, sample)
                })
                .collect();
            let expect = reference(&cfg, &w, &reqs);
            let workers = *g.choose(&[1usize, 4]);
            let gen = GenConfig { max_batch: 2, slots: 2, slot_cap: 16, workers };
            let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
            if got != expect {
                return Err(format!("{name}: mid-stream join output diverged"));
            }
            if metrics.completed != n_req {
                return Err(format!("completed {} != {n_req}", metrics.completed));
            }
            // With 2 slots and >2 requests, some admission happened at t>0.
            if metrics.batch_fill.iter().any(|&f| f > 2.0) {
                return Err("batch exceeded max_batch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn serve_rejects_invalid_requests() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig { max_batch: 2, slots: 2, slot_cap: 8, workers: 1 };
        let (tx, rx) = channel();
        let (s1, r1) = super::super::stream::stream_channel();
        let (s2, r2) = super::super::stream::stream_channel();
        let (s3, r3) = super::super::stream::stream_channel();
        let (s4, r4) = super::super::stream::stream_channel();
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 1 };
        // Empty prompt; needs prompt+max_new-1 = 9 > cap 8; max_new == 0.
        let bad = [
            GenRequest { id: 0, prompt: vec![], max_new: 2, sample: sc, stream: s1, enqueued: Instant::now() },
            GenRequest { id: 1, prompt: vec![1; 6], max_new: 4, sample: sc, stream: s2, enqueued: Instant::now() },
            GenRequest { id: 2, prompt: vec![1; 2], max_new: 0, sample: sc, stream: s3, enqueued: Instant::now() },
        ];
        for r in bad {
            tx.send(r).unwrap();
        }
        // Exact fit: 5 + 4 - 1 = 8 == cap must be ADMITTED, not rejected.
        tx.send(GenRequest {
            id: 3, prompt: vec![1; 5], max_new: 4, sample: sc, stream: s4,
            enqueued: Instant::now(),
        })
        .unwrap();
        drop(tx);
        let metrics = serve_generation(&cfg, &w, &NoOverride, &gen, rx).unwrap();
        assert_eq!(metrics.rejected, 3);
        assert_eq!(metrics.completed, 1);
        for rx in [r1, r2, r3] {
            let (tokens, done) = collect_stream(&rx);
            assert!(tokens.is_empty());
            assert_eq!(done.unwrap().finish, FinishReason::Rejected);
        }
        let (tokens, done) = collect_stream(&r4);
        assert_eq!(tokens.len(), 4);
        assert_eq!(done.unwrap().finish, FinishReason::Completed);
    }

    #[test]
    fn serve_cancelled_client_frees_slot_for_queued_request() {
        let (cfg, w) = tiny("llama-t");
        // One slot, two requests: the first client hangs up immediately, so
        // the second only runs if cancellation recycles the slot.
        let gen = GenConfig { max_batch: 1, slots: 1, slot_cap: 32, workers: 1 };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 5 };
        let (tx, rx) = channel();
        let (s1, r1) = super::super::stream::stream_channel();
        drop(r1); // client 1 gone before serving starts
        tx.send(GenRequest {
            id: 0, prompt: vec![3, 4], max_new: 20, sample: sc, stream: s1,
            enqueued: Instant::now(),
        })
        .unwrap();
        let (s2, r2) = super::super::stream::stream_channel();
        tx.send(GenRequest {
            id: 1, prompt: vec![9, 8, 7], max_new: 5, sample: sc, stream: s2,
            enqueued: Instant::now(),
        })
        .unwrap();
        drop(tx);
        let metrics = serve_generation(&cfg, &w, &NoOverride, &gen, rx).unwrap();
        assert_eq!(metrics.cancelled, 1);
        assert_eq!(metrics.completed, 2); // cancelled + completed both retire
        let (tokens, done) = collect_stream(&r2);
        let expect = generate(&cfg, &w, &NoOverride, &[9, 8, 7], 5, sc).unwrap();
        assert_eq!(tokens, expect);
        assert_eq!(done.unwrap().finish, FinishReason::Completed);
    }
}
