//! The step-level scheduler: deadline- and priority-aware continuous
//! batching over the paged KV pool.
//!
//! One scheduler thread owns the [`KvPool`] and [`PrefixTrie`] and the
//! decode loop; producers fan [`GenRequest`]s in over an mpsc channel from
//! any number of threads.  Each request moves through a small state
//! machine:
//!
//! ```text
//!   arrive ──► queued ──► active ──► Completed / Cancelled / Faulted
//!      │          │  ▲       │
//!      │          │  └───────┤ preempt (pool pressure; resumes exactly)
//!      ▼          ▼          ▼
//!   Rejected    Shed / DeadlineExceeded  (overload / expiry, any state)
//! ```
//!
//! Between decode steps the scheduler:
//!
//! 1. **drains** arrivals into a bounded admission queue.  Infeasible
//!    requests (empty prompt, `max_new == 0`, worst-case page need over
//!    the whole pool) are rejected outright.  When the queue is at
//!    `queue_cap`, the overload policy compares the arrival against the
//!    globally *worst* work the server holds (queued, preempted, or
//!    active, by the QoS order below): if the arrival is worst it is
//!    `Rejected` (pure backpressure — always the case when QoS fields are
//!    defaults), otherwise the worst request is `Shed` to make room.
//!    Shedding only ever drops the least-urgent work, which is what makes
//!    the no-priority-inversion property hold by construction,
//! 2. **kills** expired deadlines — queued, preempted, or active — with a
//!    `DeadlineExceeded` terminal (tokens already streamed remain a
//!    bit-exact prefix of the sequential output),
//! 3. **resumes** preempted sequences, most urgent first,
//! 4. **admits** queued requests in QoS order.  Admission checks
//!    *feasibility*, not worst-case reservation: a sequence claims its
//!    first page on first write and faults in the rest as it grows,
//! 5. **plans** one batched step, most urgent sequence first: prompt
//!    prefills are split into `prefill_chunk`-row pieces interleaved with
//!    neighbors' decode rows, prompts covered by the prefix trie skip the
//!    shared pages, and a fully covered prompt replays its last position
//!    for logits without writing KV,
//! 6. on pool exhaustion mid-plan, **evicts** reusable prefix-trie pages
//!    (LRU), then **preempts** the least-urgent not-yet-planned sequence
//!    that ranks strictly below the starved one — its pages are released
//!    and it re-queues with its fed-token history intact, resuming later
//!    by re-prefilling `prompt ++ already-sampled tokens` exactly,
//! 7. runs the batched step under a **watchdog**: a panic or injected
//!    fault inside the step retires only the requests whose rows failed
//!    (terminal `Faulted`), never the server.  The failed attempt is
//!    re-executed one sequence at a time — sound because the step commits
//!    pool lengths only at its very end, `prepare` is idempotent, and
//!    `push_row` overwrites deterministically, so surviving neighbors
//!    reproduce bit-identical rows (see [`super::step`]).
//!
//! **QoS order.**  Requests are ranked by
//! `(priority DESC, deadline ASC — none sorts last, arrival ASC)`.  With
//! the default QoS fields (priority 0, no deadline) this collapses to the
//! arrival-FIFO order of the pre-QoS scheduler, so default-config
//! schedules — and therefore outputs and metrics — are unchanged (pinned
//! by the regression tests below).
//!
//! **Clocks.**  Deadlines are relative; [`ClockMode::Wall`] measures them
//! in seconds of server wall-clock, [`ClockMode::Steps`] in executed
//! decode steps — a deterministic virtual clock that makes deadline and
//! inversion tests exactly reproducible.
//!
//! **Chaos.**  With [`ChaosConfig`] set, seeded deterministic faults are
//! injected into the loop: per-`(step, request)` step faults take the
//! watchdog path, and allocation faults make a sequence's first page
//! `prepare` of a step report exhaustion (driving the real
//! eviction/preemption ladder; the retry hits the true pool, so surviving
//! outputs keep their bits — only the schedule is perturbed).
//!
//! Output stays bit-identical to a fresh single-request run
//! ([`crate::model::generate::generate`]) through all of it: the batched
//! step is bit-identical per row, KV at a position is a deterministic
//! function of the token prefix (which makes shared pages and re-prefilled
//! resumes exact), and sampling state is per-request (seeded [`Rng`] from
//! the request's own [`SampleConfig::seed`], advanced once per generated
//! token regardless of scheduling).
//!
//! Progress guarantee: admission rejects any request whose worst-case page
//! need exceeds the pool, and the most urgent active sequence plans first
//! with the whole trie evictable and every lower-ranked sequence
//! preemptable — so the front of the QoS order always advances, and
//! induction retires everything.

use super::chaos::ChaosConfig;
use super::kv_pool::{KvPool, SeqId};
use super::prefix::{PrefixTrie, ROOT};
use super::step::{decode_step_batched_kv, StepRow};
use super::stream::{DoneStats, FinishReason, StreamEvent, TokenStream};
use crate::coordinator::metrics::GenServerMetrics;
use crate::model::config::ModelConfig;
use crate::model::forward::LinearOverride;
use crate::model::generate::{sample_token, SampleConfig};
use crate::model::kvc::KvCompression;
use crate::model::weights::Weights;
use crate::util::rng::Rng;
use crate::util::threads::ThreadBudget;
use crate::util::timer::Timer;
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::time::Instant;

/// One generation request.
#[derive(Debug)]
pub struct GenRequest {
    /// Caller-chosen id, echoed in [`DoneStats`].
    pub id: u64,
    /// Prompt tokens (must be non-empty).
    pub prompt: Vec<u8>,
    /// Tokens to generate (must be ≥ 1).
    pub max_new: usize,
    /// Per-request sampling configuration; `seed` makes the output
    /// deterministic regardless of co-batched neighbors.
    pub sample: SampleConfig,
    /// Streaming delivery channel back to the client.
    pub stream: TokenStream,
    /// When the client enqueued the request (for latency metrics).
    pub enqueued: Instant,
    /// Tenant id for per-tenant accounting (default 0 = untagged).
    pub tenant: u32,
    /// Scheduling priority — higher runs first (default 0).
    pub priority: u8,
    /// Relative deadline in the server's [`ClockMode`] units (seconds or
    /// steps), measured from enqueue; `None` (the default) never expires.
    pub deadline: Option<f64>,
}

impl GenRequest {
    /// A request with default QoS fields (tenant 0, priority 0, no
    /// deadline) — exactly the pre-QoS FIFO behavior.
    pub fn new(id: u64, prompt: Vec<u8>, max_new: usize, sample: SampleConfig, stream: TokenStream) -> Self {
        GenRequest {
            id,
            prompt,
            max_new,
            sample,
            stream,
            enqueued: Instant::now(),
            tenant: 0,
            priority: 0,
            deadline: None,
        }
    }
}

/// Which clock drives deadline expiry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockMode {
    /// Wall-clock seconds (production; what `--deadline-ms` means).
    Wall,
    /// One tick per executed decode step — a deterministic virtual clock
    /// for reproducible deadline tests.
    Steps,
}

/// Generation-server knobs.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// Maximum sequences active per step (the continuous-batching width;
    /// a prefill chunk adds rows beyond this, bounded by `prefill_chunk`).
    pub max_batch: usize,
    /// Total KV pages in the pool — the real memory budget.  Admission
    /// rejects a request only when its worst-case need
    /// (`⌈(prompt + max_new − 1) / page_size⌉`) exceeds this; pressure
    /// between admitted sequences is resolved by fault-in + preemption,
    /// not reservation.
    pub pages: usize,
    /// Positions per page.  Small pages waste less on short tails and
    /// share prefixes at finer grain; large pages gather less.
    pub page_size: usize,
    /// Max prompt rows fed per sequence per step (0 = whole prompt in one
    /// chunk).  Caps the latency a long arrival adds to neighbors' steps.
    pub prefill_chunk: usize,
    /// Dedupe common prompt prefixes across requests via the page trie
    /// (full pages only; output-invariant either way).
    pub prefix_share: bool,
    /// Thread budget for the batched step's GEMMs (0 = all cores);
    /// bit-identical results at every value.
    pub workers: usize,
    /// Bound on the admission queue (0 = unbounded).  At the cap, the
    /// overload policy rejects the arrival or sheds the globally
    /// least-urgent request — explicit backpressure instead of unbounded
    /// memory growth.
    pub queue_cap: usize,
    /// Clock for deadline expiry (wall seconds vs. deterministic steps).
    pub clock: ClockMode,
    /// Deterministic fault injection; `None` (and all-zero rates) is
    /// production behavior.
    pub chaos: Option<ChaosConfig>,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            max_batch: 8,
            pages: 64,
            page_size: 16,
            prefill_chunk: 16,
            prefix_share: true,
            workers: 0,
            queue_cap: 0,
            clock: ClockMode::Wall,
            chaos: None,
        }
    }
}

/// Total QoS order: higher priority first, then earliest deadline (EDF —
/// `None` sorts last), then arrival.  Smaller key = more urgent.  With
/// default QoS fields this is exactly arrival order.
type QosKey = (Reverse<u8>, u64, u64);

fn qos_key(priority: u8, deadline_at: Option<f64>, arrival: u64) -> QosKey {
    // `deadline_at` is clamped non-negative at stamping, so the f64 bit
    // pattern is monotone in the deadline.
    let d = deadline_at.map_or(u64::MAX, |t| t.max(0.0).to_bits());
    (Reverse(priority), d, arrival)
}

/// A request waiting in the bounded admission queue.
struct Queued {
    req: GenRequest,
    arrival: u64,
    /// Absolute expiry instant on the server clock (stamped at drain).
    deadline_at: Option<f64>,
}

impl Queued {
    fn key(&self) -> QosKey {
        qos_key(self.req.priority, self.deadline_at, self.arrival)
    }
}

/// One admitted sequence's scheduler state.  Survives preemption — only
/// `seq` and the trie cursor are rebuilt on resume.
struct Active {
    req: GenRequest,
    seq: SeqId,
    rng: Rng,
    /// Every token fed (or queued to feed): `prompt ++ sampled tokens that
    /// were fed back`.  `pool.len(seq)` positions of it are committed; the
    /// gap is what prefill chunks (or a resume) still owe.
    fed: Vec<u8>,
    /// Tokens generated so far (streamed tokens are never re-sent).
    produced: usize,
    /// Enqueue → first generated token, set once (survives preemption).
    ttft_s: Option<f64>,
    /// Admission order — the FIFO tiebreak inside the QoS order.
    arrival: u64,
    /// Absolute expiry instant on the server clock.
    deadline_at: Option<f64>,
    /// Trie node of the last matched/registered prompt chunk ([`ROOT`]
    /// when none) — the parent for the next chunk this request registers.
    trie_tail: usize,
    /// Prompt chunks already matched or registered into the trie.
    trie_chunks: usize,
}

impl Active {
    fn key(&self) -> QosKey {
        qos_key(self.req.priority, self.deadline_at, self.arrival)
    }
}

/// What happens to an active sequence at the end of a step.
#[derive(Clone, Copy)]
enum Fate {
    Continue,
    Finish(FinishReason),
    Preempt,
}

/// Where the overload policy found its shed victim.
enum Slot {
    Queued(usize),
    Preempted(usize),
    Active(usize),
}

/// Preemption-victim order (largest wins): least urgent first — lowest
/// priority, then latest deadline (`None` most preemptable) — preferring
/// fully-private sequences among equals (they free every page), then the
/// youngest.  With default QoS fields this is exactly the pre-QoS
/// `(!shared, arrival)` victim order.
fn victim_key(a: &Active, pool: &KvPool) -> (Reverse<u8>, u64, bool, u64) {
    (
        Reverse(a.req.priority),
        a.deadline_at.map_or(u64::MAX, |t| t.max(0.0).to_bits()),
        !pool.seq_is_shared(a.seq),
        a.arrival,
    )
}

/// The server clock: wall seconds, or executed steps as a deterministic
/// virtual time.
fn clock_now(mode: ClockMode, wall: &Timer, steps: usize) -> f64 {
    match mode {
        ClockMode::Wall => wall.elapsed_s(),
        ClockMode::Steps => steps as f64,
    }
}

/// Emit the request's single terminal event and account it.  Every exit
/// path funnels through here, which is what pins the exactly-one-`Done`
/// contract.  `served` marks requests that were actually admitted (their
/// retirement counts in `completed` and feeds the latency rings);
/// queue-level exits pass `false`.
fn send_done(
    metrics: &mut GenServerMetrics,
    req: &GenRequest,
    finish: FinishReason,
    generated: usize,
    ttft_s: Option<f64>,
    served: bool,
) {
    let latency = req.enqueued.elapsed().as_secs_f64();
    let ttft = ttft_s.unwrap_or(latency);
    if served {
        metrics.record_finish(latency, ttft);
    }
    metrics.record_terminal(req.tenant, finish, generated);
    if crate::obs::enabled() {
        crate::obs::instant("serve.request.done")
            .arg_u64("req", req.id)
            .arg_str("reason", finish.label())
            .arg_u64("generated", generated as u64);
        let key = match finish {
            FinishReason::Completed => "serve.requests.completed",
            FinishReason::Cancelled => "serve.requests.cancelled",
            FinishReason::Rejected => "serve.requests.rejected",
            FinishReason::Shed => "serve.requests.shed",
            FinishReason::DeadlineExceeded => "serve.requests.deadline_exceeded",
            FinishReason::Faulted => "serve.requests.faulted",
        };
        crate::obs::metrics::counter_add(key, 1);
        crate::obs::metrics::counter_add("serve.tokens.generated", generated as u64);
        if served {
            crate::obs::metrics::observe("serve.latency_seconds", latency);
            crate::obs::metrics::observe("serve.ttft_seconds", ttft);
        }
    }
    if finish != FinishReason::Completed {
        crate::debugln!(
            "serve",
            "req {} retired: {} after {} tokens ({:.1} ms)",
            req.id,
            finish.label(),
            generated,
            latency * 1e3
        );
    }
    let _ = req.stream.send(StreamEvent::Done(DoneStats {
        id: req.id,
        generated,
        finish,
        latency_s: latency,
        ttft_s: ttft,
    }));
}

/// Trace/metric/log hook for a preemption decision (the victim's pages
/// were just released; it re-enters the preempted queue after the step).
fn note_preempted(a: &Active) {
    if crate::obs::enabled() {
        crate::obs::instant("serve.request.preempted").arg_u64("req", a.req.id);
        crate::obs::metrics::counter_add("serve.sched.preemptions", 1);
    }
    crate::debugln!("serve", "req {} preempted (pool pressure)", a.req.id);
}

/// Give `a` a pool sequence: fork over the trie's longest registered
/// prefix of its fed history when sharing is on (sound for positions past
/// the prompt too — a chain match pins the entire token prefix, and KV at
/// a position is a deterministic function of that prefix).
fn attach_seq(a: &mut Active, pool: &mut KvPool, trie: &mut PrefixTrie, share: bool) {
    if share {
        let chain = trie.lookup(&a.fed);
        let pages: Vec<usize> = chain.iter().map(|&(_, p)| p).collect();
        a.trie_tail = chain.last().map_or(ROOT, |&(n, _)| n);
        a.trie_chunks = chain.len();
        a.seq = pool.fork_seq(&pages);
    } else {
        a.trie_tail = ROOT;
        a.trie_chunks = 0;
        a.seq = pool.new_seq();
    }
}

/// Trie nodes eviction must skip: the registration tail of every live
/// (non-evicted) active that still has prompt chunks to register — a
/// recycled tail would chain later chunks under the wrong parent.
fn pinned_tails(active: &[Active], evicted: &[usize], page_size: usize) -> Vec<usize> {
    active
        .iter()
        .enumerate()
        .filter(|(i, a)| {
            !evicted.contains(i)
                && a.trie_tail != ROOT
                && (a.trie_chunks + 1) * page_size <= a.req.prompt.len()
        })
        .map(|(_, a)| a.trie_tail)
        .collect()
}

/// Run the generation server until the request channel closes and every
/// admitted sequence has finished.  Blocks the calling thread (which
/// becomes the scheduler/owner of the pool and trie — all page refcounts
/// mutate here, between steps, which is why none of it needs locks);
/// returns accumulated metrics.  The scheduler never panics on client or
/// model misbehavior: dropped receivers degrade to cancellation and step
/// failures are isolated by the watchdog.
pub fn serve_generation(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    gen: &GenConfig,
    requests: Receiver<GenRequest>,
) -> Result<GenServerMetrics> {
    serve_generation_kv(cfg, weights, overrides, None, gen, requests)
}

/// [`serve_generation`] with optional KV-cache compression: the pool's
/// pages store rank-wide latents ([`KvPool::with_kvc`]) so the same page
/// budget admits ~(d/r)× the token positions, and every decode step routes
/// through [`decode_step_batched_kv`].  Output bits stay identical to a
/// single-request [`crate::model::generate::generate_kv`] run under the
/// SAME compression — the whole scheduling machinery (chunked prefill,
/// prefix sharing, preemption, watchdog re-execution, chaos) composes
/// unchanged because the compressed step keeps the per-row bit-identity
/// contract.  `kvc` `None` (or identity) is literally the uncompressed
/// server.
pub fn serve_generation_kv(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    kvc: Option<&KvCompression>,
    gen: &GenConfig,
    requests: Receiver<GenRequest>,
) -> Result<GenServerMetrics> {
    let max_batch = gen.max_batch.max(1);
    let page_size = gen.page_size.max(1);
    let pages = gen.pages.max(1);
    let chunk_cap = if gen.prefill_chunk == 0 { usize::MAX } else { gen.prefill_chunk };
    let step_workers = ThreadBudget::new(gen.workers).total();
    let chaos = gen.chaos.filter(|c| c.is_active());
    let mut pool = KvPool::with_kvc(cfg, pages, page_size, kvc);
    let mut trie = PrefixTrie::new(page_size);
    let mut active: Vec<Active> = Vec::new();
    let mut preempted: VecDeque<Active> = VecDeque::new();
    let mut queue: VecDeque<Queued> = VecDeque::new();
    let mut metrics = GenServerMetrics::default();
    metrics.kv_slot_bytes = pool.page_bytes() as f64 / page_size as f64;
    metrics.kv_factor_bytes = kvc.map_or(0, |c| c.factor_bytes());
    let mut open = true;
    let mut arrivals: u64 = 0;
    let wall = Timer::start();
    loop {
        // ---- drain arrivals into the bounded admission queue ----
        loop {
            let idle = active.is_empty() && preempted.is_empty() && queue.is_empty();
            let next = if !open {
                None
            } else if idle {
                // Nothing in flight: block for work (or shutdown).
                match requests.recv() {
                    Ok(r) => Some(r),
                    Err(_) => {
                        open = false;
                        None
                    }
                }
            } else {
                match requests.try_recv() {
                    Ok(r) => Some(r),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            };
            let Some(req) = next else { break };
            // A request feeds prompt + max_new - 1 positions (the final
            // sampled token is never fed back).  It is infeasible only if
            // that worst case cannot fit the ENTIRE pool.
            let infeasible = req.prompt.is_empty() || req.max_new == 0 || {
                (req.prompt.len() + req.max_new - 1).div_ceil(page_size) > pool.pages()
            };
            if infeasible {
                send_done(&mut metrics, &req, FinishReason::Rejected, 0, None, false);
                continue;
            }
            // Stamp the relative deadline into an absolute expiry on the
            // server clock.  Wall mode anchors at the client's enqueue
            // instant (queue wait counts against the deadline); the steps
            // clock can only anchor at drain.
            let now_s = clock_now(gen.clock, &wall, metrics.steps);
            let deadline_at = req.deadline.map(|d| {
                let anchor = match gen.clock {
                    ClockMode::Wall => (now_s - req.enqueued.elapsed().as_secs_f64()).max(0.0),
                    ClockMode::Steps => now_s,
                };
                anchor + d.max(0.0)
            });
            // ---- overload policy at the queue bound ----
            if gen.queue_cap > 0 && queue.len() >= gen.queue_cap {
                let new_key = qos_key(req.priority, deadline_at, arrivals);
                // Find the globally WORST work the server holds (largest
                // QoS key across queued, preempted, and active) — work is
                // only dropped when everything kept is more urgent, which
                // is what rules out priority inversion.
                let mut worst: Option<(QosKey, Slot)> = None;
                let mut consider = |key: QosKey, slot: Slot| {
                    if worst.as_ref().map_or(true, |(wk, _)| key > *wk) {
                        worst = Some((key, slot));
                    }
                };
                for (k, q) in queue.iter().enumerate() {
                    consider(q.key(), Slot::Queued(k));
                }
                for (k, a) in preempted.iter().enumerate() {
                    consider(a.key(), Slot::Preempted(k));
                }
                for (k, a) in active.iter().enumerate() {
                    consider(a.key(), Slot::Active(k));
                }
                match worst {
                    Some((wk, slot)) if wk > new_key => match slot {
                        Slot::Queued(k) => {
                            if let Some(q) = queue.remove(k) {
                                send_done(&mut metrics, &q.req, FinishReason::Shed, 0, None, false);
                            }
                        }
                        Slot::Preempted(k) => {
                            // Its sequence was already released at preemption.
                            if let Some(a) = preempted.remove(k) {
                                send_done(&mut metrics, &a.req, FinishReason::Shed, a.produced, a.ttft_s, true);
                            }
                        }
                        Slot::Active(k) => {
                            let a = active.swap_remove(k);
                            pool.release_seq(a.seq);
                            send_done(&mut metrics, &a.req, FinishReason::Shed, a.produced, a.ttft_s, true);
                        }
                    },
                    _ => {
                        // The arrival itself is the least urgent work in
                        // sight: pure backpressure.
                        send_done(&mut metrics, &req, FinishReason::Rejected, 0, None, false);
                        continue;
                    }
                }
            }
            if crate::obs::enabled() {
                crate::obs::instant("serve.request.queued")
                    .arg_u64("req", req.id)
                    .arg_u64("tenant", req.tenant as u64)
                    .arg_u64("prompt", req.prompt.len() as u64);
            }
            queue.push_back(Queued { req, arrival: arrivals, deadline_at });
            arrivals += 1;
            metrics.peak_queue = metrics.peak_queue.max(queue.len());
        }
        // ---- kill expired deadlines in every state ----
        let now_s = clock_now(gen.clock, &wall, metrics.steps);
        let expired = |deadline_at: Option<f64>| deadline_at.is_some_and(|t| now_s >= t);
        let mut k = 0;
        while k < queue.len() {
            if expired(queue[k].deadline_at) {
                if let Some(q) = queue.remove(k) {
                    send_done(&mut metrics, &q.req, FinishReason::DeadlineExceeded, 0, None, false);
                }
            } else {
                k += 1;
            }
        }
        let mut k = 0;
        while k < preempted.len() {
            if expired(preempted[k].deadline_at) {
                if let Some(a) = preempted.remove(k) {
                    send_done(&mut metrics, &a.req, FinishReason::DeadlineExceeded, a.produced, a.ttft_s, true);
                }
            } else {
                k += 1;
            }
        }
        let mut k = 0;
        while k < active.len() {
            if expired(active[k].deadline_at) {
                let a = active.swap_remove(k);
                pool.release_seq(a.seq);
                send_done(&mut metrics, &a.req, FinishReason::DeadlineExceeded, a.produced, a.ttft_s, true);
            } else {
                k += 1;
            }
        }
        // ---- resume preempted sequences first (they keep seniority) ----
        preempted.make_contiguous().sort_by_key(Active::key);
        while active.len() < max_batch && !preempted.is_empty() {
            while pool.free_pages() == 0 {
                let pins = pinned_tails(&active, &[], page_size);
                if !trie.evict_lru(&mut pool, &pins) {
                    break;
                }
            }
            if pool.free_pages() == 0 {
                break;
            }
            let Some(mut a) = preempted.pop_front() else { break };
            attach_seq(&mut a, &mut pool, &mut trie, gen.prefix_share);
            if crate::obs::enabled() {
                crate::obs::instant("serve.request.resumed").arg_u64("req", a.req.id);
            }
            active.push(a);
        }
        // ---- admit queued requests, most urgent first ----
        while active.len() < max_batch
            && (pool.free_pages() > 0 || trie.entries() > 0)
            && !queue.is_empty()
        {
            let best = (0..queue.len()).min_by_key(|&k| queue[k].key());
            let Some(q) = best.and_then(|k| queue.remove(k)) else { break };
            let rng = Rng::new(q.req.sample.seed);
            let fed = q.req.prompt.clone();
            let mut a = Active {
                req: q.req,
                seq: 0,
                rng,
                fed,
                produced: 0,
                ttft_s: None,
                arrival: q.arrival,
                deadline_at: q.deadline_at,
                trie_tail: ROOT,
                trie_chunks: 0,
            };
            attach_seq(&mut a, &mut pool, &mut trie, gen.prefix_share);
            if crate::obs::enabled() {
                crate::obs::instant("serve.request.admitted")
                    .arg_u64("req", a.req.id)
                    .arg_u64("shared_pages", a.trie_chunks as u64);
            }
            active.push(a);
        }
        if active.is_empty() {
            if preempted.is_empty() && queue.is_empty() {
                if !open {
                    break;
                }
                continue; // back to the blocking recv
            }
            continue; // retry resuming/admitting (eviction frees pages)
        }
        // ---- plan one step: QoS order, chunked prefill, fault-in ----
        let step_no = metrics.steps as u64;
        let mut plan_sp = crate::obs::span("serve.plan");
        if plan_sp.is_recording() {
            plan_sp.arg_u64("step", step_no).arg_u64("batch", active.len() as u64);
        }
        let mut order: Vec<usize> = (0..active.len()).collect();
        order.sort_by_key(|&i| active[i].key());
        let mut rank: Vec<usize> = vec![0; active.len()];
        for (r, &i) in order.iter().enumerate() {
            rank[i] = r;
        }
        let mut rows: Vec<StepRow> = Vec::new();
        // Per-active contiguous row ranges — the watchdog's isolation
        // units.
        let mut groups: Vec<(usize, Range<usize>)> = Vec::new();
        let mut logits_row: Vec<Option<usize>> = vec![None; active.len()];
        let mut planned: Vec<bool> = vec![false; active.len()];
        let mut evicted: Vec<usize> = Vec::new();
        for &i in &order {
            if evicted.contains(&i) {
                continue;
            }
            let seq = active[i].seq;
            let committed = pool.len(seq);
            let flen = active[i].fed.len();
            let row_start = rows.len();
            if committed == flen {
                // The whole fed history is already cached (full prefix
                // cover): replay the last position for its logits only.
                rows.push(StepRow {
                    seq,
                    token: active[i].fed[flen - 1],
                    pos: flen - 1,
                    needs_logits: true,
                    write_kv: false,
                });
                logits_row[i] = Some(rows.len() - 1);
                planned[i] = true;
                groups.push((i, row_start..rows.len()));
                continue;
            }
            let mut end = committed + (flen - committed).min(chunk_cap);
            let mut pos = committed;
            // Chaos: at most one simulated allocation failure per
            // sequence per step.
            let mut alloc_faults = match &chaos {
                Some(c) if c.alloc_fault(step_no, active[i].req.id) => 1u32,
                _ => 0,
            };
            while pos < end {
                if alloc_faults > 0 {
                    alloc_faults -= 1;
                    // Simulated exhaustion: drive ONE rung of the real
                    // recovery ladder (trie eviction, else preemption),
                    // then retry against the true pool — the fault
                    // perturbs only the schedule, never the output bits.
                    let pins = pinned_tails(&active, &evicted, page_size);
                    if !trie.evict_lru(&mut pool, &pins) {
                        let victim = (0..active.len())
                            .filter(|&j| !planned[j] && !evicted.contains(&j) && rank[j] > rank[i])
                            .max_by_key(|&j| victim_key(&active[j], &pool));
                        if let Some(v) = victim {
                            pool.release_seq(active[v].seq);
                            evicted.push(v);
                            metrics.preemptions += 1;
                            note_preempted(&active[v]);
                        }
                    }
                    continue;
                }
                if pool.prepare(seq, pos).is_some() {
                    pos += 1;
                    continue;
                }
                // Pool exhausted: shed reusable prefix pages first...
                let pins = pinned_tails(&active, &evicted, page_size);
                if trie.evict_lru(&mut pool, &pins) {
                    continue;
                }
                // ...then preempt the least-urgent unplanned sequence
                // ranked strictly below this one (never above — that
                // would livelock), preferring fully-private victims among
                // equal keys (they free every page).
                let victim = (0..active.len())
                    .filter(|&j| !planned[j] && !evicted.contains(&j) && rank[j] > rank[i])
                    .max_by_key(|&j| victim_key(&active[j], &pool));
                match victim {
                    Some(v) => {
                        pool.release_seq(active[v].seq);
                        evicted.push(v);
                        metrics.preemptions += 1;
                        note_preempted(&active[v]);
                    }
                    None => end = pos, // nothing left to shed: feed a short
                                       // (possibly empty) chunk this step
                }
            }
            for p in committed..end {
                rows.push(StepRow {
                    seq,
                    token: active[i].fed[p],
                    pos: p,
                    needs_logits: p + 1 == flen,
                    write_kv: true,
                });
                if p < active[i].req.prompt.len() {
                    metrics.prefill_rows += 1;
                }
            }
            if end > committed {
                planned[i] = true;
                if end == flen {
                    logits_row[i] = Some(rows.len() - 1);
                }
                groups.push((i, row_start..rows.len()));
            }
        }
        if plan_sp.is_recording() {
            plan_sp
                .arg_u64("rows", rows.len() as u64)
                .arg_u64("prefill_rows", rows.iter().filter(|r| r.write_kv).count() as u64)
                .arg_u64("evictions", evicted.len() as u64);
        }
        drop(plan_sp);
        // ---- one batched decode step, guarded by the watchdog ----
        let vocab = cfg.vocab;
        let injected: Vec<bool> = {
            let mut v = vec![false; active.len()];
            if let Some(c) = &chaos {
                for &(i, _) in &groups {
                    v[i] = c.step_fault(step_no, active[i].req.id);
                }
            }
            v
        };
        let inject_any = injected.iter().any(|&b| b);
        let mut fault_flags: Vec<bool> = vec![false; active.len()];
        let step_t = Timer::start();
        let mut decode_sp = crate::obs::span("serve.decode");
        if decode_sp.is_recording() {
            decode_sp
                .arg_u64("step", step_no)
                .arg_u64("rows", rows.len() as u64)
                .arg_u64("workers", step_workers as u64);
        }
        // &mut KvPool is not UnwindSafe by default; the wrap is sound
        // because a failed attempt leaves the pool in a re-executable
        // state — committed lengths are untouched (the step calls
        // `set_len` only at its very end), `prepare` is idempotent, and
        // `push_row` deterministically overwrites.
        let batched = if inject_any {
            // An injected fault aborts the batched attempt up front
            // (nothing executed), exactly like an early step error.
            Err(anyhow::anyhow!("chaos: injected step fault (step {step_no})"))
        } else {
            match catch_unwind(AssertUnwindSafe(|| {
                decode_step_batched_kv(cfg, weights, overrides, kvc, &mut pool, &rows, step_workers)
            })) {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("panic in batched decode step {step_no}")),
            }
        };
        let logits = match batched {
            Ok(l) => l,
            Err(_) => {
                // Watchdog: the batched attempt died.  Re-execute one
                // sequence at a time — bit-identical to the batched run by
                // the step's per-row contract — and retire only the rows
                // that still fail.  Rows of logit-less groups land in the
                // zeroed buffer and are never read.
                let mut merged = vec![0.0f32; rows.len() * vocab];
                for (i, range) in &groups {
                    if injected[*i] {
                        fault_flags[*i] = true;
                        continue;
                    }
                    let sub = &rows[range.clone()];
                    let one = catch_unwind(AssertUnwindSafe(|| {
                        decode_step_batched_kv(cfg, weights, overrides, kvc, &mut pool, sub, step_workers)
                    }));
                    match one {
                        Ok(Ok(l)) => {
                            merged[range.start * vocab..range.end * vocab].copy_from_slice(&l);
                        }
                        _ => {
                            fault_flags[*i] = true;
                            crate::warnln!(
                                "serve",
                                "watchdog: req {} faulted at step {step_no}; retiring it alone",
                                active[*i].req.id
                            );
                        }
                    }
                }
                merged
            }
        };
        drop(decode_sp);
        let step_s = step_t.elapsed_s();
        let occupancy = pool.pages_in_use() as f64 / pool.pages() as f64;
        metrics.record_step(step_s, (active.len() - evicted.len()) as f64, occupancy);
        if crate::obs::enabled() {
            crate::obs::metrics::counter_add("serve.steps", 1);
            crate::obs::metrics::observe("serve.step_seconds", step_s);
            crate::obs::metrics::gauge_set("serve.pool.occupancy", occupancy);
            crate::obs::metrics::gauge_set("serve.queue.depth", queue.len() as f64);
            crate::obs::metrics::gauge_set("serve.trie.entries", trie.entries() as f64);
        }
        // ---- sample / stream for every sequence whose logits we read ----
        let mut fate: Vec<Fate> = (0..active.len()).map(|_| Fate::Continue).collect();
        for &v in &evicted {
            fate[v] = Fate::Preempt;
        }
        for (i, &failed) in fault_flags.iter().enumerate() {
            if failed {
                fate[i] = Fate::Finish(FinishReason::Faulted);
            }
        }
        for i in 0..active.len() {
            if !matches!(fate[i], Fate::Continue) {
                continue;
            }
            let Some(ri) = logits_row[i] else { continue };
            let a = &mut active[i];
            let next = sample_token(&logits[ri * vocab..(ri + 1) * vocab], a.req.sample, &mut a.rng);
            let index = a.produced;
            a.produced += 1;
            metrics.generated += 1;
            if a.ttft_s.is_none() {
                a.ttft_s = Some(a.req.enqueued.elapsed().as_secs_f64());
            }
            let delivered = a.req.stream.send(StreamEvent::Token { index, byte: next });
            if !delivered {
                fate[i] = Fate::Finish(FinishReason::Cancelled);
            } else if a.produced == a.req.max_new {
                fate[i] = Fate::Finish(FinishReason::Completed);
            } else {
                a.fed.push(next);
            }
        }
        // ---- register newly completed full prompt pages in the trie ----
        // Before retirement on purpose: a finishing request's prompt stays
        // shareable (the trie's refs keep its pages alive past release).
        if gen.prefix_share {
            for (i, a) in active.iter_mut().enumerate() {
                if matches!(fate[i], Fate::Preempt) {
                    continue;
                }
                let committed = pool.len(a.seq);
                let shareable = a.req.prompt.len().min(committed);
                while (a.trie_chunks + 1) * page_size <= shareable {
                    let idx = a.trie_chunks;
                    let chunk = &a.fed[idx * page_size..(idx + 1) * page_size];
                    let page = pool.page_at(a.seq, idx);
                    a.trie_tail = trie.register(&mut pool, a.trie_tail, chunk, page);
                    a.trie_chunks += 1;
                }
            }
        }
        // ---- retire / requeue ----
        let mut still: Vec<Active> = Vec::with_capacity(active.len());
        for (i, a) in active.drain(..).enumerate() {
            match fate[i] {
                Fate::Continue => still.push(a),
                Fate::Preempt => preempted.push_back(a), // seq already released
                Fate::Finish(finish) => {
                    pool.release_seq(a.seq);
                    send_done(&mut metrics, &a.req, finish, a.produced, a.ttft_s, true);
                }
            }
        }
        active = still;
    }
    trie.clear(&mut pool);
    metrics.prefix_hit_tokens = trie.hit_positions;
    metrics.prefix_miss_tokens = trie.miss_positions;
    metrics.wall_s = wall.elapsed_s();
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::NoOverride;
    use crate::model::generate::generate;
    use crate::serve::stream::{collect_stream, stream_channel};
    use crate::util::prop::check;
    use std::sync::mpsc::channel;

    fn tiny(name: &str) -> (ModelConfig, Weights) {
        crate::serve::test_util::tiny(name, 47)
    }

    /// Preload `reqs`, serve to completion on this thread, return each
    /// request's streamed tokens (in request order) and the metrics —
    /// the shared harness from `crate::bench`.
    fn run_server(
        cfg: &ModelConfig,
        w: &Weights,
        gen: &GenConfig,
        reqs: Vec<(Vec<u8>, usize, SampleConfig)>,
    ) -> (Vec<Vec<u8>>, GenServerMetrics) {
        crate::bench::drive_preloaded(cfg, w, &NoOverride, gen, reqs)
    }

    fn reference(cfg: &ModelConfig, w: &Weights, reqs: &[(Vec<u8>, usize, SampleConfig)]) -> Vec<Vec<u8>> {
        reqs.iter()
            .map(|(prompt, max_new, sample)| {
                generate(cfg, w, &NoOverride, prompt, *max_new, *sample).unwrap()
            })
            .collect()
    }

    /// Preload explicit [`GenRequest`]s (QoS fields and all), serve on
    /// this thread, and hand back each request's drained stream.
    fn run_qos(
        cfg: &ModelConfig,
        w: &Weights,
        gen: &GenConfig,
        reqs: Vec<GenRequest>,
        events: Vec<std::sync::mpsc::Receiver<StreamEvent>>,
    ) -> (Vec<(Vec<u8>, Option<DoneStats>)>, GenServerMetrics) {
        let (tx, rx) = channel();
        for r in reqs {
            tx.send(r).unwrap();
        }
        drop(tx);
        let metrics = serve_generation(cfg, w, &NoOverride, gen, rx).unwrap();
        let outs = events.iter().map(collect_stream).collect();
        (outs, metrics)
    }

    #[test]
    fn serve_matches_sequential_generate_all_families() {
        for name in ["llama-t", "opt-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..3)
                .map(|i| {
                    (
                        (0..(2 + i)).map(|t| ((t * 67 + i * 13) % 251) as u8).collect(),
                        4 + i,
                        SampleConfig { temperature: 0.9, top_k: 20, seed: 100 + i as u64 },
                    )
                })
                .collect();
            let expect = reference(&cfg, &w, &reqs);
            let gen = GenConfig {
                max_batch: 3,
                pages: 12,
                page_size: 4,
                prefill_chunk: 2,
                prefix_share: true,
                workers: 1,
                ..GenConfig::default()
            };
            let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
            assert_eq!(got, expect, "{name}: served tokens must equal sequential generate");
            assert_eq!(metrics.completed, 3);
            assert_eq!(metrics.generated, 4 + 5 + 6);
        }
    }

    #[test]
    fn serve_bit_identical_across_batch_sizes_and_workers() {
        let (cfg, w) = tiny("llama-t");
        let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..8)
            .map(|i| {
                (
                    (0..(1 + i % 4)).map(|t| ((t * 41 + i * 7) % 256) as u8).collect(),
                    3 + i % 3,
                    SampleConfig { temperature: 0.8, top_k: 12, seed: i as u64 },
                )
            })
            .collect();
        let expect = reference(&cfg, &w, &reqs);
        // The FULL advertised grid: batch {1, 3, 8} × workers {1, 4}.
        for &max_batch in &[1usize, 3, 8] {
            for &workers in &[1usize, 4] {
                let gen = GenConfig {
                    max_batch,
                    pages: 24,
                    page_size: 4,
                    prefill_chunk: 3,
                    prefix_share: true,
                    workers,
                    ..GenConfig::default()
                };
                let (got, metrics) = run_server(&cfg, &w, &gen, reqs.clone());
                assert_eq!(
                    got, expect,
                    "batch={max_batch} workers={workers}: output must be bit-identical"
                );
                assert!(metrics.batch_fill.iter().all(|&f| f <= max_batch as f64));
                assert_eq!(metrics.completed, 8);
            }
        }
    }

    /// Mid-stream join/leave: with a narrow batch, sequences join as pool
    /// room frees up at arbitrary steps and must still match a fresh
    /// sequential run — across families, page sizes, sharing, and workers.
    #[test]
    fn serve_mid_stream_join_leave_matches_sequential() {
        check("continuous-batching parity", 4, |g| {
            let name = *g.choose(&["llama-t", "opt-t", "mistral-t"]);
            let (cfg, w) = tiny(name);
            let n_req = g.usize_in(3, 6);
            let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..n_req)
                .map(|_| {
                    let plen = g.usize_in(1, 5);
                    let prompt = (0..plen).map(|_| g.usize_in(0, 256) as u8).collect();
                    let max_new = g.usize_in(1, 6);
                    let sample = SampleConfig {
                        temperature: 1.0,
                        top_k: 8,
                        seed: g.rng.next_u64(),
                    };
                    (prompt, max_new, sample)
                })
                .collect();
            let expect = reference(&cfg, &w, &reqs);
            let workers = *g.choose(&[1usize, 4]);
            let gen = GenConfig {
                max_batch: 2,
                pages: 24,
                page_size: *g.choose(&[1usize, 4, 16]),
                prefill_chunk: *g.choose(&[0usize, 1, 3]),
                prefix_share: g.bool(),
                workers,
                ..GenConfig::default()
            };
            let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
            if got != expect {
                return Err(format!("{name}: mid-stream join output diverged"));
            }
            if metrics.completed != n_req {
                return Err(format!("completed {} != {n_req}", metrics.completed));
            }
            // With 2 active slots and >2 requests, some admission happened
            // mid-stream.
            if metrics.batch_fill.iter().any(|&f| f > 2.0) {
                return Err("batch exceeded max_batch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn serve_rejects_invalid_requests() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 2,
            pages: 2,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            ..GenConfig::default()
        };
        let (tx, rx) = channel();
        let (s1, r1) = stream_channel();
        let (s2, r2) = stream_channel();
        let (s3, r3) = stream_channel();
        let (s4, r4) = stream_channel();
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 1 };
        // Empty prompt; needs ⌈(6+4-1)/4⌉ = 3 pages > 2; max_new == 0.
        let bad = [
            GenRequest::new(0, vec![], 2, sc, s1),
            GenRequest::new(1, vec![1; 6], 4, sc, s2),
            GenRequest::new(2, vec![1; 2], 0, sc, s3),
        ];
        for r in bad {
            tx.send(r).unwrap();
        }
        // Exact fit: ⌈(5+4-1)/4⌉ = 2 == pool pages must be ADMITTED.
        tx.send(GenRequest::new(3, vec![1; 5], 4, sc, s4)).unwrap();
        drop(tx);
        let metrics = serve_generation(&cfg, &w, &NoOverride, &gen, rx).unwrap();
        assert_eq!(metrics.rejected, 3);
        assert_eq!(metrics.completed, 1);
        for rx in [r1, r2, r3] {
            let (tokens, done) = collect_stream(&rx);
            assert!(tokens.is_empty());
            assert_eq!(done.unwrap().finish, FinishReason::Rejected);
        }
        let (tokens, done) = collect_stream(&r4);
        assert_eq!(tokens.len(), 4);
        assert_eq!(done.unwrap().finish, FinishReason::Completed);
    }

    /// Satellite regression: the old scheduler capped every request at the
    /// per-slot reservation (capacity / slots rows).  A request needing far
    /// more than that — but fitting the pool as a whole — must now be
    /// admitted and complete bit-identically.
    #[test]
    fn serve_admits_request_beyond_old_per_slot_cap() {
        let (cfg, w) = tiny("llama-t");
        // 8 pages × 4 positions = 32 rows of pool; the old per-slot cap at
        // max_batch 4 would have been 32 / 4 = 8 rows.  This request needs
        // 6 + 15 - 1 = 20 rows: over the old cap, within the pool.
        let gen = GenConfig {
            max_batch: 4,
            pages: 8,
            page_size: 4,
            prefill_chunk: 4,
            prefix_share: true,
            workers: 1,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.7, top_k: 16, seed: 9 };
        let prompt: Vec<u8> = (0..6).map(|t| (t * 39 + 1) as u8).collect();
        let reqs = vec![(prompt.clone(), 15, sc)];
        let expect = reference(&cfg, &w, &reqs);
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(metrics.rejected, 0, "must not be rejected");
        assert_eq!(metrics.completed, 1);
        assert_eq!(got, expect);
    }

    /// Two requests sharing a long prompt prefix: the second skips the
    /// shared pages' prefill entirely, output stays bit-identical to both
    /// sequential generate and a no-sharing server run.
    #[test]
    fn serve_prefix_sharing_skips_prefill_bit_identically() {
        let (cfg, w) = tiny("llama-t");
        let system: Vec<u8> = (0..8).map(|t| (t * 23 + 5) as u8).collect(); // 2 full pages
        let mut p1 = system.clone();
        p1.extend([70, 71]);
        let mut p2 = system.clone();
        p2.extend([90, 91, 92]);
        let reqs = vec![
            (p1, 4, SampleConfig { temperature: 0.8, top_k: 10, seed: 21 }),
            (p2, 5, SampleConfig { temperature: 0.8, top_k: 10, seed: 22 }),
        ];
        let expect = reference(&cfg, &w, &reqs);
        // max_batch 1 serializes the two requests, so the first has
        // registered its prompt pages before the second is admitted.
        let base = GenConfig {
            max_batch: 1,
            pages: 8,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: true,
            workers: 1,
            ..GenConfig::default()
        };
        let (got, metrics) = run_server(&cfg, &w, &base, reqs.clone());
        assert_eq!(got, expect, "shared-prefix output must equal sequential");
        // Request 2's first 8 positions came from the trie: its prefill fed
        // only the 3-token tail (plus request 1's full 10 rows).
        assert_eq!(metrics.prefix_hit_tokens, 8);
        assert_eq!(metrics.prefill_rows, 10 + 3);
        assert!(metrics.prefix_hit_rate() > 0.0);
        // And sharing must be output-invariant.
        let off = GenConfig { prefix_share: false, ..base };
        let (got_off, m_off) = run_server(&cfg, &w, &off, reqs);
        assert_eq!(got_off, expect);
        assert_eq!(m_off.prefix_hit_tokens, 0);
        assert_eq!(m_off.prefill_rows, 10 + 11);
    }

    /// A prompt FULLY covered by shared pages (length an exact multiple of
    /// the page size) takes the replay path — no prefill rows at all — and
    /// still matches sequential generate.
    #[test]
    fn serve_full_prefix_cover_replays_last_position() {
        let (cfg, w) = tiny("opt-t");
        let prompt: Vec<u8> = (0..8).map(|t| (t * 31 + 9) as u8).collect(); // exactly 2 pages
        let reqs = vec![
            (prompt.clone(), 3, SampleConfig { temperature: 0.6, top_k: 8, seed: 31 }),
            (prompt.clone(), 4, SampleConfig { temperature: 0.6, top_k: 8, seed: 32 }),
        ];
        let expect = reference(&cfg, &w, &reqs);
        let gen = GenConfig {
            max_batch: 1,
            pages: 8,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: true,
            workers: 1,
            ..GenConfig::default()
        };
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(got, expect);
        assert_eq!(metrics.prefix_hit_tokens, 8, "request 2's whole prompt was cached");
        assert_eq!(metrics.prefill_rows, 8, "only request 1 prefilled");
    }

    /// A pool too small for both requests' worst case forces preemption:
    /// the younger request is evicted mid-flight, resumes after the older
    /// finishes, and both outputs stay bit-identical to sequential runs.
    #[test]
    fn serve_preemption_resumes_bit_identically() {
        let (cfg, w) = tiny("llama-t");
        // Each request needs 3 + 3 - 1 = 5 rows → 3 pages of 2; the pool
        // holds exactly 3 pages, so both can never be resident at full
        // length simultaneously.
        let gen = GenConfig {
            max_batch: 2,
            pages: 3,
            page_size: 2,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            ..GenConfig::default()
        };
        let reqs = vec![
            (vec![11, 12, 13], 3, SampleConfig { temperature: 0.9, top_k: 6, seed: 41 }),
            (vec![21, 22, 23], 3, SampleConfig { temperature: 0.9, top_k: 6, seed: 42 }),
        ];
        let expect = reference(&cfg, &w, &reqs);
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(got, expect, "preempted + resumed output must be bit-identical");
        assert_eq!(metrics.completed, 2);
        assert!(metrics.preemptions >= 1, "this pool must have preempted");
    }

    #[test]
    fn serve_cancelled_client_frees_pool_for_queued_request() {
        let (cfg, w) = tiny("llama-t");
        // One active slot, two requests: the first client hangs up
        // immediately, so the second only runs if cancellation frees room.
        let gen = GenConfig {
            max_batch: 1,
            pages: 16,
            page_size: 2,
            prefill_chunk: 0,
            prefix_share: true,
            workers: 1,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 5 };
        let (tx, rx) = channel();
        let (s1, r1) = stream_channel();
        drop(r1); // client 1 gone before serving starts
        tx.send(GenRequest::new(0, vec![3, 4], 20, sc, s1)).unwrap();
        let (s2, r2) = stream_channel();
        tx.send(GenRequest::new(1, vec![9, 8, 7], 5, sc, s2)).unwrap();
        drop(tx);
        let metrics = serve_generation(&cfg, &w, &NoOverride, &gen, rx).unwrap();
        assert_eq!(metrics.cancelled, 1);
        assert_eq!(metrics.completed, 2); // cancelled + completed both retire
        let (tokens, done) = collect_stream(&r2);
        let expect = generate(&cfg, &w, &NoOverride, &[9, 8, 7], 5, sc).unwrap();
        assert_eq!(tokens, expect);
        assert_eq!(done.unwrap().finish, FinishReason::Completed);
    }

    // ---- QoS / overload / chaos tests ----

    /// Satellite regression pin: with default QoS fields the new scheduler
    /// is the old FIFO scheduler — same outputs, no shed/deadline/fault
    /// terminals, all accounting under tenant 0.
    #[test]
    fn serve_default_qos_is_fifo_regression() {
        let (cfg, w) = tiny("mistral-t");
        let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..6)
            .map(|i| {
                (
                    (0..(1 + i % 3)).map(|t| ((t * 91 + i * 17) % 250) as u8).collect(),
                    2 + i % 4,
                    SampleConfig { temperature: 1.0, top_k: 10, seed: 900 + i as u64 },
                )
            })
            .collect();
        let expect = reference(&cfg, &w, &reqs);
        let gen = GenConfig {
            max_batch: 2,
            pages: 16,
            page_size: 4,
            prefill_chunk: 2,
            prefix_share: true,
            workers: 1,
            ..GenConfig::default()
        };
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(got, expect, "default QoS must reproduce the FIFO scheduler's output");
        assert_eq!(metrics.completed, 6);
        assert_eq!(metrics.shed, 0);
        assert_eq!(metrics.deadline_exceeded, 0);
        assert_eq!(metrics.faulted, 0);
        assert_eq!(metrics.tenants.len(), 1, "all default requests account to tenant 0");
        let t0 = &metrics.tenants[&0];
        assert_eq!(t0.requests, 6);
        assert_eq!(t0.completed, 6);
        assert_eq!(t0.generated as usize, metrics.generated);
    }

    /// Deadlines on the deterministic steps clock: a request that cannot
    /// finish in time is killed mid-stream with a `DeadlineExceeded`
    /// terminal, and the tokens it did stream are a bit-exact prefix of
    /// sequential generate.
    #[test]
    fn serve_deadline_exceeded_kills_expired_request() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 1,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            clock: ClockMode::Steps,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.7, top_k: 12, seed: 61 };
        let (s1, r1) = stream_channel();
        let mut r = GenRequest::new(0, vec![5, 6], 10, sc, s1);
        r.deadline = Some(3.0); // three decode steps, far short of 10 tokens
        let (outs, metrics) = run_qos(&cfg, &w, &gen, vec![r], vec![r1]);
        let (tokens, done) = &outs[0];
        let done = done.as_ref().unwrap();
        assert_eq!(done.finish, FinishReason::DeadlineExceeded);
        assert_eq!(metrics.deadline_exceeded, 1);
        assert_eq!(metrics.completed, 1, "an admitted deadline kill still retires");
        // Steps clock: admitted at step 0, killed when the clock reaches 3
        // → exactly 3 tokens (prompt prefill + first token share step 0).
        assert_eq!(tokens.len(), 3);
        let expect = generate(&cfg, &w, &NoOverride, &[5, 6], 10, sc).unwrap();
        assert_eq!(tokens[..], expect[..3], "streamed prefix must stay bit-exact");
    }

    /// A deadline that is already hopeless at arrival kills the request in
    /// the queue — exactly one `DeadlineExceeded`, zero tokens — while a
    /// neighbor without a deadline completes with full parity.
    #[test]
    fn serve_deadline_expired_in_queue_never_runs() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 1,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            clock: ClockMode::Steps,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 62 };
        let (s1, r1) = stream_channel();
        let mut dead = GenRequest::new(0, vec![9, 9], 4, sc, s1);
        dead.deadline = Some(0.0);
        let (s2, r2) = stream_channel();
        let live = GenRequest::new(1, vec![1, 2, 3], 4, sc, s2);
        let (outs, metrics) = run_qos(&cfg, &w, &gen, vec![dead, live], vec![r1, r2]);
        assert_eq!(outs[0].0.len(), 0);
        assert_eq!(outs[0].1.as_ref().unwrap().finish, FinishReason::DeadlineExceeded);
        let expect = generate(&cfg, &w, &NoOverride, &[1, 2, 3], 4, sc).unwrap();
        assert_eq!(outs[1].0, expect);
        assert_eq!(outs[1].1.as_ref().unwrap().finish, FinishReason::Completed);
        assert_eq!(metrics.deadline_exceeded, 1);
        assert_eq!(metrics.completed, 1, "queue-level kills never count as served");
    }

    /// Bounded admission queue, equal QoS: overflow arrivals are rejected
    /// (pure backpressure — FIFO keeps the oldest), each with exactly one
    /// `Rejected` terminal, and the queued request completes untouched.
    #[test]
    fn serve_bounded_queue_rejects_overflow() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 1,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            queue_cap: 1,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 71 };
        let mut reqs = Vec::new();
        let mut events = Vec::new();
        for i in 0..4 {
            let (s, r) = stream_channel();
            reqs.push(GenRequest::new(i, vec![10 + i as u8, 20], 3, sc, s));
            events.push(r);
        }
        let (outs, metrics) = run_qos(&cfg, &w, &gen, reqs, events);
        // All four arrive in one burst before any admission: the first
        // fills the queue, the rest are its overflow.
        let expect = generate(&cfg, &w, &NoOverride, &[10, 20], 3, sc).unwrap();
        assert_eq!(outs[0].0, expect);
        assert_eq!(outs[0].1.as_ref().unwrap().finish, FinishReason::Completed);
        for o in &outs[1..] {
            assert!(o.0.is_empty());
            assert_eq!(o.1.as_ref().unwrap().finish, FinishReason::Rejected);
        }
        assert_eq!(metrics.rejected, 3);
        assert_eq!(metrics.shed, 0, "equal QoS never sheds — arrivals are the worst");
        assert_eq!(metrics.peak_queue, 1);
    }

    /// At the queue bound a higher-priority arrival displaces the queued
    /// low-priority request, which gets exactly one `Shed` terminal.
    #[test]
    fn serve_overload_sheds_lowest_priority() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 1,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            queue_cap: 1,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 72 };
        let (s1, r1) = stream_channel();
        let low = GenRequest::new(0, vec![3, 4], 3, sc, s1);
        let (s2, r2) = stream_channel();
        let mut high = GenRequest::new(1, vec![5, 6], 3, sc, s2);
        high.priority = 5;
        let (outs, metrics) = run_qos(&cfg, &w, &gen, vec![low, high], vec![r1, r2]);
        assert!(outs[0].0.is_empty());
        assert_eq!(outs[0].1.as_ref().unwrap().finish, FinishReason::Shed);
        let expect = generate(&cfg, &w, &NoOverride, &[5, 6], 3, sc).unwrap();
        assert_eq!(outs[1].0, expect);
        assert_eq!(outs[1].1.as_ref().unwrap().finish, FinishReason::Completed);
        assert_eq!(metrics.shed, 1);
        assert_eq!(metrics.rejected, 0);
    }

    /// The acceptance pin: deterministic seeded overload where the shed
    /// set and the completed set are exact, and no completed request had a
    /// strictly later deadline than any shed request — shedding always
    /// drops the least-urgent work, so priority inversion cannot occur.
    #[test]
    fn serve_no_deadline_priority_inversion() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 1,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            queue_cap: 2,
            clock: ClockMode::Steps,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 73 };
        // Descending deadlines: every arrival is more urgent than all the
        // queued work, so each displacement sheds the latest deadline.
        let deadlines = [90.0, 80.0, 70.0, 60.0, 50.0, 40.0];
        let mut reqs = Vec::new();
        let mut events = Vec::new();
        for (i, &d) in deadlines.iter().enumerate() {
            let (s, r) = stream_channel();
            let mut q = GenRequest::new(i as u64, vec![30 + i as u8, 31], 2, sc, s);
            q.deadline = Some(d);
            reqs.push(q);
            events.push(r);
        }
        let (outs, metrics) = run_qos(&cfg, &w, &gen, reqs, events);
        let mut shed_deadlines = Vec::new();
        let mut completed_deadlines = Vec::new();
        for (i, (_, done)) in outs.iter().enumerate() {
            match done.as_ref().unwrap().finish {
                FinishReason::Shed => shed_deadlines.push(deadlines[i]),
                FinishReason::Completed => completed_deadlines.push(deadlines[i]),
                other => panic!("request {i}: unexpected terminal {other:?}"),
            }
        }
        // Exact deterministic outcome: the four latest deadlines shed, the
        // two earliest complete.
        assert_eq!(shed_deadlines, vec![90.0, 80.0, 70.0, 60.0]);
        assert_eq!(completed_deadlines, vec![50.0, 40.0]);
        assert_eq!(metrics.shed, 4);
        assert_eq!(metrics.deadline_exceeded, 0, "survivors finished inside their deadlines");
        // The property itself: nothing kept was less urgent than anything
        // dropped.
        for &c in &completed_deadlines {
            for &s in &shed_deadlines {
                assert!(c <= s, "completed deadline {c} after shedding earlier deadline {s}");
            }
        }
    }

    /// Priority orders admission: a high-priority late arrival runs before
    /// an earlier low-priority request, meeting a steps-clock deadline
    /// that FIFO order would have busted (the low-priority request alone
    /// needs more steps than the whole deadline).
    #[test]
    fn serve_priority_overtakes_fifo_for_deadline() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 1,
            pages: 32,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            clock: ClockMode::Steps,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.8, top_k: 8, seed: 81 };
        let (s1, r1) = stream_channel();
        let slow = GenRequest::new(0, vec![40, 41], 12, sc, s1); // 12 steps alone
        let (s2, r2) = stream_channel();
        let mut urgent = GenRequest::new(1, vec![50, 51, 52], 3, sc, s2);
        urgent.priority = 3;
        urgent.deadline = Some(8.0); // < the 12 steps FIFO would wait
        let (outs, metrics) = run_qos(&cfg, &w, &gen, vec![slow, urgent], vec![r1, r2]);
        let expect_urgent = generate(&cfg, &w, &NoOverride, &[50, 51, 52], 3, sc).unwrap();
        assert_eq!(outs[1].0, expect_urgent);
        assert_eq!(
            outs[1].1.as_ref().unwrap().finish,
            FinishReason::Completed,
            "priority admission must beat the deadline FIFO would miss"
        );
        let expect_slow = generate(&cfg, &w, &NoOverride, &[40, 41], 12, sc).unwrap();
        assert_eq!(outs[0].0, expect_slow, "the overtaken request still completes exactly");
        assert_eq!(metrics.deadline_exceeded, 0);
        assert_eq!(metrics.completed, 2);
    }

    /// Under pool pressure a high-priority arrival preempts the
    /// EARLIER-arrived low-priority sequence (the QoS generalization of
    /// youngest-first), and the victim still resumes bit-identically.
    #[test]
    fn serve_priority_preemption_resumes_bit_identically() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 2,
            pages: 3,
            page_size: 2,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            ..GenConfig::default()
        };
        let sc1 = SampleConfig { temperature: 0.9, top_k: 6, seed: 91 };
        let sc2 = SampleConfig { temperature: 0.9, top_k: 6, seed: 92 };
        let (s1, r1) = stream_channel();
        let low = GenRequest::new(0, vec![11, 12, 13], 3, sc1, s1);
        let (s2, r2) = stream_channel();
        let mut high = GenRequest::new(1, vec![21, 22, 23], 3, sc2, s2);
        high.priority = 7;
        let (outs, metrics) = run_qos(&cfg, &w, &gen, vec![low, high], vec![r1, r2]);
        let expect_low = generate(&cfg, &w, &NoOverride, &[11, 12, 13], 3, sc1).unwrap();
        let expect_high = generate(&cfg, &w, &NoOverride, &[21, 22, 23], 3, sc2).unwrap();
        assert_eq!(outs[0].0, expect_low, "preempted low-priority output must resume exactly");
        assert_eq!(outs[1].0, expect_high);
        assert_eq!(metrics.completed, 2);
        assert!(metrics.preemptions >= 1, "this pool must have preempted the low-priority seq");
    }

    /// Injected step fault isolates exactly one request: the faulted one
    /// retires with `Faulted` and zero tokens, its batch neighbor
    /// completes with full sequential parity, the server never panics.
    #[test]
    fn serve_injected_fault_isolates_single_request() {
        let (cfg, w) = tiny("llama-t");
        let c = ChaosConfig { seed: 7, step_fault_rate: 0.2, alloc_fail_rate: 0.0 };
        // The chaos decision is a pure function of (step, id): pick one id
        // that faults at step 0 and one that never faults over any
        // plausible lifetime.
        let faulty = (0u64..10_000).find(|&id| c.step_fault(0, id)).expect("some id faults at step 0");
        let clean = (0u64..10_000)
            .find(|&id| id != faulty && (0..16).all(|s| !c.step_fault(s, id)))
            .expect("some id never faults");
        let gen = GenConfig {
            max_batch: 2,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            chaos: Some(c),
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.7, top_k: 10, seed: 55 };
        let (s1, r1) = stream_channel();
        let (s2, r2) = stream_channel();
        let reqs = vec![
            GenRequest::new(clean, vec![60, 61], 3, sc, s1),
            GenRequest::new(faulty, vec![70, 71], 3, sc, s2),
        ];
        let (outs, metrics) = run_qos(&cfg, &w, &gen, reqs, vec![r1, r2]);
        let expect = generate(&cfg, &w, &NoOverride, &[60, 61], 3, sc).unwrap();
        assert_eq!(outs[0].0, expect, "the surviving neighbor must stay bit-identical");
        assert_eq!(outs[0].1.as_ref().unwrap().finish, FinishReason::Completed);
        assert!(outs[1].0.is_empty(), "faulted at its first step: no tokens");
        assert_eq!(outs[1].1.as_ref().unwrap().finish, FinishReason::Faulted);
        assert_eq!(metrics.faulted, 1);
        assert_eq!(metrics.completed, 2, "both admitted requests retired");
    }

    /// A genuinely panicking model: every step attempt panics, the
    /// watchdog catches each one, every request retires with `Faulted`
    /// and exactly one `Done`, and `serve_generation` returns `Ok`.
    #[test]
    fn serve_watchdog_survives_panicking_model() {
        struct PanicOverride;
        impl LinearOverride for PanicOverride {
            fn apply(&self, _: &str, _: &[f32], _: usize, _: usize) -> Option<Vec<f32>> {
                panic!("injected model panic");
            }
        }
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 2,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 57 };
        let (tx, rx) = channel();
        let (s1, r1) = stream_channel();
        let (s2, r2) = stream_channel();
        tx.send(GenRequest::new(0, vec![1, 2], 3, sc, s1)).unwrap();
        tx.send(GenRequest::new(1, vec![3, 4], 3, sc, s2)).unwrap();
        drop(tx);
        // Silence the default panic hook for the duration: the panics are
        // intentional and caught by the watchdog.
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let result = serve_generation(&cfg, &w, &PanicOverride, &gen, rx);
        std::panic::set_hook(hook);
        let metrics = result.expect("the scheduler must survive model panics");
        assert_eq!(metrics.faulted, 2);
        assert_eq!(metrics.completed, 2);
        assert_eq!(metrics.generated, 0);
        for rx in [r1, r2] {
            let (tokens, done) = collect_stream(&rx);
            assert!(tokens.is_empty());
            assert_eq!(done.unwrap().finish, FinishReason::Faulted);
        }
    }

    /// The hard watchdog case: the batched attempt panics PARTWAY through
    /// the step — after some K/V rows were already pushed — and the
    /// per-sequence re-run recovers every request bit-identically with
    /// zero casualties.  The override panics on the 5th projection of any
    /// wide (≥ 3 row) batch, i.e. after layer 0's K/V pushes; per-group
    /// re-runs are narrower and sail through.
    #[test]
    fn serve_watchdog_recovers_partial_step_bit_identically() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct PanicMidWideBatch {
            wide_calls: AtomicUsize,
        }
        impl LinearOverride for PanicMidWideBatch {
            fn apply(&self, _: &str, _: &[f32], rows: usize, _: usize) -> Option<Vec<f32>> {
                if rows >= 3 && self.wide_calls.fetch_add(1, Ordering::SeqCst) == 4 {
                    panic!("injected mid-step panic");
                }
                None // dense forward otherwise
            }
        }
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 2,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.8, top_k: 9, seed: 58 };
        // Two 2-token prompts: the first step batches 4 prefill rows
        // (panics mid-step); each group re-run is 2 rows (survives); every
        // later step is 2 decode rows (survives).
        let reqs = vec![
            (vec![12, 13], 3, sc),
            (vec![14, 15], 4, sc),
        ];
        let expect = reference(&cfg, &w, &reqs);
        let over = PanicMidWideBatch { wide_calls: AtomicUsize::new(0) };
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let (got, metrics) = crate::bench::drive_preloaded(&cfg, &w, &over, &gen, reqs);
        std::panic::set_hook(hook);
        assert!(over.wide_calls.load(Ordering::SeqCst) >= 5, "the wide attempt must have run");
        assert_eq!(got, expect, "recovered requests must stay bit-identical");
        assert_eq!(metrics.faulted, 0, "the watchdog recovered everyone");
        assert_eq!(metrics.completed, 2);
    }

    /// Allocation-failure injection at rate 1.0: every sequence's first
    /// page claim of every step is refused, forcing the recovery ladder
    /// constantly — yet all outputs stay bit-identical and all requests
    /// complete (alloc faults are transient by construction).
    #[test]
    fn serve_alloc_fault_injection_preserves_parity() {
        let (cfg, w) = tiny("opt-t");
        let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..3)
            .map(|i| {
                (
                    (0..(2 + i)).map(|t| ((t * 53 + i * 29) % 240) as u8).collect(),
                    3 + i,
                    SampleConfig { temperature: 0.9, top_k: 14, seed: 500 + i as u64 },
                )
            })
            .collect();
        let expect = reference(&cfg, &w, &reqs);
        let gen = GenConfig {
            max_batch: 3,
            pages: 16,
            page_size: 2,
            prefill_chunk: 2,
            prefix_share: true,
            workers: 1,
            chaos: Some(ChaosConfig { seed: 13, step_fault_rate: 0.0, alloc_fail_rate: 1.0 }),
            ..GenConfig::default()
        };
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(got, expect, "alloc faults may perturb the schedule, never the bits");
        assert_eq!(metrics.completed, 3);
        assert_eq!(metrics.faulted, 0);
    }

    /// Per-tenant accounting: terminals and generated tokens are bucketed
    /// by the request's tenant id.
    #[test]
    fn serve_tenant_accounting_buckets_terminals() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 2,
            pages: 16,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
            ..GenConfig::default()
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 64 };
        let mut reqs = Vec::new();
        let mut events = Vec::new();
        for (i, (tenant, max_new)) in [(1u32, 2usize), (1, 3), (2, 4)].iter().enumerate() {
            let (s, r) = stream_channel();
            let mut q = GenRequest::new(i as u64, vec![i as u8 + 1, 2], *max_new, sc, s);
            q.tenant = *tenant;
            reqs.push(q);
            events.push(r);
        }
        let (outs, metrics) = run_qos(&cfg, &w, &gen, reqs, events);
        for o in &outs {
            assert_eq!(o.1.as_ref().unwrap().finish, FinishReason::Completed);
        }
        assert_eq!(metrics.tenants.len(), 2);
        let t1 = &metrics.tenants[&1];
        let t2 = &metrics.tenants[&2];
        assert_eq!((t1.requests, t1.completed, t1.generated), (2, 2, 5));
        assert_eq!((t2.requests, t2.completed, t2.generated), (1, 1, 4));
        assert!(metrics.wall_s > 0.0);
        assert!(metrics.tenant_tokens_per_s(1) > 0.0);
        assert_eq!(metrics.tenant_tokens_per_s(3), 0.0);
    }
}
