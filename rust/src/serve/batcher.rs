//! The step-level scheduler: continuous batching over the paged KV pool.
//!
//! One scheduler thread owns the [`KvPool`] and [`PrefixTrie`] and the
//! decode loop; producers fan [`GenRequest`]s in over an mpsc channel from
//! any number of threads.  Between decode steps the scheduler:
//!
//! 1. **resumes** previously preempted sequences (oldest first),
//! 2. **admits** queued requests — admission checks *feasibility* (the
//!    request's worst-case page need fits the whole pool), not worst-case
//!    reservation: a sequence claims its first page on first write and
//!    faults in the rest as it grows,
//! 3. **plans** one batched step, oldest sequence first: prompt prefills
//!    are split into `prefill_chunk`-row pieces interleaved with neighbors'
//!    decode rows (one long arrival can't stall in-flight streams), prompts
//!    covered by the prefix trie skip straight past the shared pages, and a
//!    prompt *fully* covered replays its last position for logits without
//!    writing KV,
//! 4. on pool exhaustion mid-plan, **evicts** reusable prefix-trie pages
//!    (LRU), then **preempts** the youngest not-yet-planned sequence that
//!    is younger than the starved one — its pages are released and it
//!    re-queues with its fed-token history intact, resuming later by
//!    re-prefilling `prompt ++ already-sampled tokens` deterministically
//!    (tokens already streamed are never re-sampled or re-sent).
//!
//! Output stays bit-identical to a fresh single-request run
//! ([`crate::model::generate::generate`]) through all of it: the batched
//! step is bit-identical per row, KV at a position is a deterministic
//! function of the token prefix (which makes shared pages and re-prefilled
//! resumes exact), and sampling state is per-request (seeded [`Rng`] from
//! the request's own [`SampleConfig::seed`], advanced once per generated
//! token regardless of scheduling).
//!
//! Progress guarantee: admission rejects any request whose worst-case page
//! need exceeds the pool, and the oldest active sequence plans first with
//! the whole trie evictable and every younger sequence preemptable — so the
//! oldest always advances, and induction retires everything.

use super::kv_pool::{KvPool, SeqId};
use super::prefix::{PrefixTrie, ROOT};
use super::step::{decode_step_batched, StepRow};
use super::stream::{DoneStats, FinishReason, StreamEvent, TokenStream};
use crate::coordinator::metrics::GenServerMetrics;
use crate::model::config::ModelConfig;
use crate::model::forward::LinearOverride;
use crate::model::generate::{sample_token, SampleConfig};
use crate::model::weights::Weights;
use crate::util::rng::Rng;
use crate::util::threads::ThreadBudget;
use crate::util::timer::Timer;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::time::Instant;

/// One generation request.
#[derive(Debug)]
pub struct GenRequest {
    /// Caller-chosen id, echoed in [`DoneStats`].
    pub id: u64,
    /// Prompt tokens (must be non-empty).
    pub prompt: Vec<u8>,
    /// Tokens to generate (must be ≥ 1).
    pub max_new: usize,
    /// Per-request sampling configuration; `seed` makes the output
    /// deterministic regardless of co-batched neighbors.
    pub sample: SampleConfig,
    /// Streaming delivery channel back to the client.
    pub stream: TokenStream,
    /// When the client enqueued the request (for latency metrics).
    pub enqueued: Instant,
}

/// Generation-server knobs.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// Maximum sequences active per step (the continuous-batching width;
    /// a prefill chunk adds rows beyond this, bounded by `prefill_chunk`).
    pub max_batch: usize,
    /// Total KV pages in the pool — the real memory budget.  Admission
    /// rejects a request only when its worst-case need
    /// (`⌈(prompt + max_new − 1) / page_size⌉`) exceeds this; pressure
    /// between admitted sequences is resolved by fault-in + preemption,
    /// not reservation.
    pub pages: usize,
    /// Positions per page.  Small pages waste less on short tails and
    /// share prefixes at finer grain; large pages gather less.
    pub page_size: usize,
    /// Max prompt rows fed per sequence per step (0 = whole prompt in one
    /// chunk).  Caps the latency a long arrival adds to neighbors' steps.
    pub prefill_chunk: usize,
    /// Dedupe common prompt prefixes across requests via the page trie
    /// (full pages only; output-invariant either way).
    pub prefix_share: bool,
    /// Thread budget for the batched step's GEMMs (0 = all cores);
    /// bit-identical results at every value.
    pub workers: usize,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            max_batch: 8,
            pages: 64,
            page_size: 16,
            prefill_chunk: 16,
            prefix_share: true,
            workers: 0,
        }
    }
}

/// One admitted sequence's scheduler state.  Survives preemption — only
/// `seq` and the trie cursor are rebuilt on resume.
struct Active {
    req: GenRequest,
    seq: SeqId,
    rng: Rng,
    /// Every token fed (or queued to feed): `prompt ++ sampled tokens that
    /// were fed back`.  `pool.len(seq)` positions of it are committed; the
    /// gap is what prefill chunks (or a resume) still owe.
    fed: Vec<u8>,
    /// Tokens generated so far (streamed tokens are never re-sent).
    produced: usize,
    /// Enqueue → first generated token, set once (survives preemption).
    ttft_s: Option<f64>,
    /// Admission order — planning priority and preemption seniority.
    arrival: u64,
    /// Trie node of the last matched/registered prompt chunk ([`ROOT`]
    /// when none) — the parent for the next chunk this request registers.
    trie_tail: usize,
    /// Prompt chunks already matched or registered into the trie.
    trie_chunks: usize,
}

/// What happens to an active sequence at the end of a step.
#[derive(Clone, Copy)]
enum Fate {
    Continue,
    Finish(FinishReason),
    Preempt,
}

/// Give `a` a pool sequence: fork over the trie's longest registered
/// prefix of its fed history when sharing is on (sound for positions past
/// the prompt too — a chain match pins the entire token prefix, and KV at
/// a position is a deterministic function of that prefix).
fn attach_seq(a: &mut Active, pool: &mut KvPool, trie: &mut PrefixTrie, share: bool) {
    if share {
        let chain = trie.lookup(&a.fed);
        let pages: Vec<usize> = chain.iter().map(|&(_, p)| p).collect();
        a.trie_tail = chain.last().map_or(ROOT, |&(n, _)| n);
        a.trie_chunks = chain.len();
        a.seq = pool.fork_seq(&pages);
    } else {
        a.trie_tail = ROOT;
        a.trie_chunks = 0;
        a.seq = pool.new_seq();
    }
}

/// Trie nodes eviction must skip: the registration tail of every live
/// (non-evicted) active that still has prompt chunks to register — a
/// recycled tail would chain later chunks under the wrong parent.
fn pinned_tails(active: &[Active], evicted: &[usize], page_size: usize) -> Vec<usize> {
    active
        .iter()
        .enumerate()
        .filter(|(i, a)| {
            !evicted.contains(i)
                && a.trie_tail != ROOT
                && (a.trie_chunks + 1) * page_size <= a.req.prompt.len()
        })
        .map(|(_, a)| a.trie_tail)
        .collect()
}

/// Run the generation server until the request channel closes and every
/// admitted sequence has finished.  Blocks the calling thread (which
/// becomes the scheduler/owner of the pool and trie — all page refcounts
/// mutate here, between steps, which is why none of it needs locks);
/// returns accumulated metrics.
pub fn serve_generation(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    gen: &GenConfig,
    requests: Receiver<GenRequest>,
) -> Result<GenServerMetrics> {
    let max_batch = gen.max_batch.max(1);
    let page_size = gen.page_size.max(1);
    let pages = gen.pages.max(1);
    let chunk_cap = if gen.prefill_chunk == 0 { usize::MAX } else { gen.prefill_chunk };
    let step_workers = ThreadBudget::new(gen.workers).total();
    let mut pool = KvPool::new(cfg, pages, page_size);
    let mut trie = PrefixTrie::new(page_size);
    let mut active: Vec<Active> = Vec::new();
    let mut preempted: VecDeque<Active> = VecDeque::new();
    let mut metrics = GenServerMetrics::default();
    let mut open = true;
    let mut arrivals: u64 = 0;
    let wall = Timer::start();
    loop {
        // ---- resume preempted sequences first (they keep seniority) ----
        while active.len() < max_batch && !preempted.is_empty() {
            while pool.free_pages() == 0 {
                let pins = pinned_tails(&active, &[], page_size);
                if !trie.evict_lru(&mut pool, &pins) {
                    break;
                }
            }
            if pool.free_pages() == 0 {
                break;
            }
            let mut a = preempted.pop_front().expect("checked non-empty");
            attach_seq(&mut a, &mut pool, &mut trie, gen.prefix_share);
            active.push(a);
        }
        // ---- admission: feasibility-checked, first page faults in later ----
        while open && active.len() < max_batch && (pool.free_pages() > 0 || trie.entries() > 0) {
            let next = if active.is_empty() && preempted.is_empty() {
                // Nothing in flight: block for work (or shutdown).
                match requests.recv() {
                    Ok(r) => Some(r),
                    Err(_) => {
                        open = false;
                        None
                    }
                }
            } else {
                match requests.try_recv() {
                    Ok(r) => Some(r),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            };
            let Some(req) = next else { break };
            // A request feeds prompt + max_new - 1 positions (the final
            // sampled token is never fed back).  It is infeasible only if
            // that worst case cannot fit the ENTIRE pool — there is no
            // per-slot cap anymore.
            let infeasible = req.prompt.is_empty() || req.max_new == 0 || {
                (req.prompt.len() + req.max_new - 1).div_ceil(page_size) > pool.pages()
            };
            if infeasible {
                let latency = req.enqueued.elapsed().as_secs_f64();
                let _ = req.stream.send(StreamEvent::Done(DoneStats {
                    id: req.id,
                    generated: 0,
                    finish: FinishReason::Rejected,
                    latency_s: latency,
                    ttft_s: latency,
                }));
                metrics.rejected += 1;
                continue;
            }
            let rng = Rng::new(req.sample.seed);
            let fed = req.prompt.clone();
            let mut a = Active {
                req,
                seq: 0,
                rng,
                fed,
                produced: 0,
                ttft_s: None,
                arrival: arrivals,
                trie_tail: ROOT,
                trie_chunks: 0,
            };
            arrivals += 1;
            attach_seq(&mut a, &mut pool, &mut trie, gen.prefix_share);
            active.push(a);
        }
        if active.is_empty() {
            if preempted.is_empty() {
                if !open {
                    break;
                }
                continue; // back to the blocking recv
            }
            continue; // retry resuming (eviction above frees pages)
        }
        // ---- plan one step: oldest first, chunked prefill, fault-in ----
        let mut order: Vec<usize> = (0..active.len()).collect();
        order.sort_by_key(|&i| active[i].arrival);
        let mut rows: Vec<StepRow> = Vec::new();
        let mut logits_row: Vec<Option<usize>> = vec![None; active.len()];
        let mut planned: Vec<bool> = vec![false; active.len()];
        let mut evicted: Vec<usize> = Vec::new();
        for &i in &order {
            if evicted.contains(&i) {
                continue;
            }
            let seq = active[i].seq;
            let committed = pool.len(seq);
            let flen = active[i].fed.len();
            if committed == flen {
                // The whole fed history is already cached (full prefix
                // cover): replay the last position for its logits only.
                rows.push(StepRow {
                    seq,
                    token: active[i].fed[flen - 1],
                    pos: flen - 1,
                    needs_logits: true,
                    write_kv: false,
                });
                logits_row[i] = Some(rows.len() - 1);
                planned[i] = true;
                continue;
            }
            let mut end = committed + (flen - committed).min(chunk_cap);
            let mut pos = committed;
            while pos < end {
                if pool.prepare(seq, pos).is_some() {
                    pos += 1;
                    continue;
                }
                // Pool exhausted: shed reusable prefix pages first...
                let pins = pinned_tails(&active, &evicted, page_size);
                if trie.evict_lru(&mut pool, &pins) {
                    continue;
                }
                // ...then preempt the youngest unplanned sequence younger
                // than this one (never a senior — that would livelock),
                // preferring fully-private victims (they free every page).
                let victim = (0..active.len())
                    .filter(|&j| {
                        !planned[j]
                            && !evicted.contains(&j)
                            && active[j].arrival > active[i].arrival
                    })
                    .max_by_key(|&j| (!pool.seq_is_shared(active[j].seq), active[j].arrival));
                match victim {
                    Some(v) => {
                        pool.release_seq(active[v].seq);
                        evicted.push(v);
                        metrics.preemptions += 1;
                    }
                    None => end = pos, // nothing left to shed: feed a short
                                       // (possibly empty) chunk this step
                }
            }
            for p in committed..end {
                rows.push(StepRow {
                    seq,
                    token: active[i].fed[p],
                    pos: p,
                    needs_logits: p + 1 == flen,
                    write_kv: true,
                });
                if p < active[i].req.prompt.len() {
                    metrics.prefill_rows += 1;
                }
            }
            if end > committed {
                planned[i] = true;
                if end == flen {
                    logits_row[i] = Some(rows.len() - 1);
                }
            }
        }
        // ---- one batched decode step over the planned rows ----
        let step_t = Timer::start();
        let logits = decode_step_batched(cfg, weights, overrides, &mut pool, &rows, step_workers)?;
        metrics.record_step(
            step_t.elapsed_s(),
            (active.len() - evicted.len()) as f64,
            pool.pages_in_use() as f64 / pool.pages() as f64,
        );
        // ---- sample / stream for every sequence whose logits we read ----
        let vocab = cfg.vocab;
        let mut fate: Vec<Fate> = (0..active.len()).map(|_| Fate::Continue).collect();
        for &v in &evicted {
            fate[v] = Fate::Preempt;
        }
        for i in 0..active.len() {
            let Some(ri) = logits_row[i] else { continue };
            let a = &mut active[i];
            let next = sample_token(&logits[ri * vocab..(ri + 1) * vocab], a.req.sample, &mut a.rng);
            let index = a.produced;
            a.produced += 1;
            metrics.generated += 1;
            if a.ttft_s.is_none() {
                a.ttft_s = Some(a.req.enqueued.elapsed().as_secs_f64());
            }
            let delivered = a.req.stream.send(StreamEvent::Token { index, byte: next });
            if !delivered {
                fate[i] = Fate::Finish(FinishReason::Cancelled);
            } else if a.produced == a.req.max_new {
                fate[i] = Fate::Finish(FinishReason::Completed);
            } else {
                a.fed.push(next);
            }
        }
        // ---- register newly completed full prompt pages in the trie ----
        // Before retirement on purpose: a finishing request's prompt stays
        // shareable (the trie's refs keep its pages alive past release).
        if gen.prefix_share {
            for (i, a) in active.iter_mut().enumerate() {
                if matches!(fate[i], Fate::Preempt) {
                    continue;
                }
                let committed = pool.len(a.seq);
                let shareable = a.req.prompt.len().min(committed);
                while (a.trie_chunks + 1) * page_size <= shareable {
                    let idx = a.trie_chunks;
                    let chunk = &a.fed[idx * page_size..(idx + 1) * page_size];
                    let page = pool.page_at(a.seq, idx);
                    a.trie_tail = trie.register(&mut pool, a.trie_tail, chunk, page);
                    a.trie_chunks += 1;
                }
            }
        }
        // ---- retire / requeue ----
        let mut still: Vec<Active> = Vec::with_capacity(active.len());
        for (i, a) in active.drain(..).enumerate() {
            match fate[i] {
                Fate::Continue => still.push(a),
                Fate::Preempt => preempted.push_back(a), // seq already released
                Fate::Finish(finish) => {
                    pool.release_seq(a.seq);
                    let latency = a.req.enqueued.elapsed().as_secs_f64();
                    let ttft = a.ttft_s.unwrap_or(latency);
                    metrics.record_finish(latency, ttft);
                    if finish == FinishReason::Cancelled {
                        metrics.cancelled += 1;
                    }
                    let _ = a.req.stream.send(StreamEvent::Done(DoneStats {
                        id: a.req.id,
                        generated: a.produced,
                        finish,
                        latency_s: latency,
                        ttft_s: ttft,
                    }));
                }
            }
        }
        active = still;
        preempted.make_contiguous().sort_by_key(|a| a.arrival);
    }
    trie.clear(&mut pool);
    metrics.prefix_hit_tokens = trie.hit_positions;
    metrics.prefix_miss_tokens = trie.miss_positions;
    metrics.wall_s = wall.elapsed_s();
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::NoOverride;
    use crate::model::generate::generate;
    use crate::serve::stream::collect_stream;
    use crate::util::prop::check;
    use std::sync::mpsc::channel;

    fn tiny(name: &str) -> (ModelConfig, Weights) {
        crate::serve::test_util::tiny(name, 47)
    }

    /// Preload `reqs`, serve to completion on this thread, return each
    /// request's streamed tokens (in request order) and the metrics —
    /// the shared harness from `crate::bench`.
    fn run_server(
        cfg: &ModelConfig,
        w: &Weights,
        gen: &GenConfig,
        reqs: Vec<(Vec<u8>, usize, SampleConfig)>,
    ) -> (Vec<Vec<u8>>, GenServerMetrics) {
        crate::bench::drive_preloaded(cfg, w, &NoOverride, gen, reqs)
    }

    fn reference(cfg: &ModelConfig, w: &Weights, reqs: &[(Vec<u8>, usize, SampleConfig)]) -> Vec<Vec<u8>> {
        reqs.iter()
            .map(|(prompt, max_new, sample)| {
                generate(cfg, w, &NoOverride, prompt, *max_new, *sample).unwrap()
            })
            .collect()
    }

    #[test]
    fn serve_matches_sequential_generate_all_families() {
        for name in ["llama-t", "opt-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..3)
                .map(|i| {
                    (
                        (0..(2 + i)).map(|t| ((t * 67 + i * 13) % 251) as u8).collect(),
                        4 + i,
                        SampleConfig { temperature: 0.9, top_k: 20, seed: 100 + i as u64 },
                    )
                })
                .collect();
            let expect = reference(&cfg, &w, &reqs);
            let gen = GenConfig {
                max_batch: 3,
                pages: 12,
                page_size: 4,
                prefill_chunk: 2,
                prefix_share: true,
                workers: 1,
            };
            let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
            assert_eq!(got, expect, "{name}: served tokens must equal sequential generate");
            assert_eq!(metrics.completed, 3);
            assert_eq!(metrics.generated, 4 + 5 + 6);
        }
    }

    #[test]
    fn serve_bit_identical_across_batch_sizes_and_workers() {
        let (cfg, w) = tiny("llama-t");
        let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..8)
            .map(|i| {
                (
                    (0..(1 + i % 4)).map(|t| ((t * 41 + i * 7) % 256) as u8).collect(),
                    3 + i % 3,
                    SampleConfig { temperature: 0.8, top_k: 12, seed: i as u64 },
                )
            })
            .collect();
        let expect = reference(&cfg, &w, &reqs);
        // The FULL advertised grid: batch {1, 3, 8} × workers {1, 4}.
        for &max_batch in &[1usize, 3, 8] {
            for &workers in &[1usize, 4] {
                let gen = GenConfig {
                    max_batch,
                    pages: 24,
                    page_size: 4,
                    prefill_chunk: 3,
                    prefix_share: true,
                    workers,
                };
                let (got, metrics) = run_server(&cfg, &w, &gen, reqs.clone());
                assert_eq!(
                    got, expect,
                    "batch={max_batch} workers={workers}: output must be bit-identical"
                );
                assert!(metrics.batch_fill.iter().all(|&f| f <= max_batch as f64));
                assert_eq!(metrics.completed, 8);
            }
        }
    }

    /// Mid-stream join/leave: with a narrow batch, sequences join as pool
    /// room frees up at arbitrary steps and must still match a fresh
    /// sequential run — across families, page sizes, sharing, and workers.
    #[test]
    fn serve_mid_stream_join_leave_matches_sequential() {
        check("continuous-batching parity", 4, |g| {
            let name = *g.choose(&["llama-t", "opt-t", "mistral-t"]);
            let (cfg, w) = tiny(name);
            let n_req = g.usize_in(3, 6);
            let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..n_req)
                .map(|_| {
                    let plen = g.usize_in(1, 5);
                    let prompt = (0..plen).map(|_| g.usize_in(0, 256) as u8).collect();
                    let max_new = g.usize_in(1, 6);
                    let sample = SampleConfig {
                        temperature: 1.0,
                        top_k: 8,
                        seed: g.rng.next_u64(),
                    };
                    (prompt, max_new, sample)
                })
                .collect();
            let expect = reference(&cfg, &w, &reqs);
            let workers = *g.choose(&[1usize, 4]);
            let gen = GenConfig {
                max_batch: 2,
                pages: 24,
                page_size: *g.choose(&[1usize, 4, 16]),
                prefill_chunk: *g.choose(&[0usize, 1, 3]),
                prefix_share: g.bool(),
                workers,
            };
            let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
            if got != expect {
                return Err(format!("{name}: mid-stream join output diverged"));
            }
            if metrics.completed != n_req {
                return Err(format!("completed {} != {n_req}", metrics.completed));
            }
            // With 2 active slots and >2 requests, some admission happened
            // mid-stream.
            if metrics.batch_fill.iter().any(|&f| f > 2.0) {
                return Err("batch exceeded max_batch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn serve_rejects_invalid_requests() {
        let (cfg, w) = tiny("llama-t");
        let gen = GenConfig {
            max_batch: 2,
            pages: 2,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
        };
        let (tx, rx) = channel();
        let (s1, r1) = super::super::stream::stream_channel();
        let (s2, r2) = super::super::stream::stream_channel();
        let (s3, r3) = super::super::stream::stream_channel();
        let (s4, r4) = super::super::stream::stream_channel();
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 1 };
        // Empty prompt; needs ⌈(6+4-1)/4⌉ = 3 pages > 2; max_new == 0.
        let bad = [
            GenRequest { id: 0, prompt: vec![], max_new: 2, sample: sc, stream: s1, enqueued: Instant::now() },
            GenRequest { id: 1, prompt: vec![1; 6], max_new: 4, sample: sc, stream: s2, enqueued: Instant::now() },
            GenRequest { id: 2, prompt: vec![1; 2], max_new: 0, sample: sc, stream: s3, enqueued: Instant::now() },
        ];
        for r in bad {
            tx.send(r).unwrap();
        }
        // Exact fit: ⌈(5+4-1)/4⌉ = 2 == pool pages must be ADMITTED.
        tx.send(GenRequest {
            id: 3, prompt: vec![1; 5], max_new: 4, sample: sc, stream: s4,
            enqueued: Instant::now(),
        })
        .unwrap();
        drop(tx);
        let metrics = serve_generation(&cfg, &w, &NoOverride, &gen, rx).unwrap();
        assert_eq!(metrics.rejected, 3);
        assert_eq!(metrics.completed, 1);
        for rx in [r1, r2, r3] {
            let (tokens, done) = collect_stream(&rx);
            assert!(tokens.is_empty());
            assert_eq!(done.unwrap().finish, FinishReason::Rejected);
        }
        let (tokens, done) = collect_stream(&r4);
        assert_eq!(tokens.len(), 4);
        assert_eq!(done.unwrap().finish, FinishReason::Completed);
    }

    /// Satellite regression: the old scheduler capped every request at the
    /// per-slot reservation (capacity / slots rows).  A request needing far
    /// more than that — but fitting the pool as a whole — must now be
    /// admitted and complete bit-identically.
    #[test]
    fn serve_admits_request_beyond_old_per_slot_cap() {
        let (cfg, w) = tiny("llama-t");
        // 8 pages × 4 positions = 32 rows of pool; the old per-slot cap at
        // max_batch 4 would have been 32 / 4 = 8 rows.  This request needs
        // 6 + 15 - 1 = 20 rows: over the old cap, within the pool.
        let gen = GenConfig {
            max_batch: 4,
            pages: 8,
            page_size: 4,
            prefill_chunk: 4,
            prefix_share: true,
            workers: 1,
        };
        let sc = SampleConfig { temperature: 0.7, top_k: 16, seed: 9 };
        let prompt: Vec<u8> = (0..6).map(|t| (t * 39 + 1) as u8).collect();
        let reqs = vec![(prompt.clone(), 15, sc)];
        let expect = reference(&cfg, &w, &reqs);
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(metrics.rejected, 0, "must not be rejected");
        assert_eq!(metrics.completed, 1);
        assert_eq!(got, expect);
    }

    /// Two requests sharing a long prompt prefix: the second skips the
    /// shared pages' prefill entirely, output stays bit-identical to both
    /// sequential generate and a no-sharing server run.
    #[test]
    fn serve_prefix_sharing_skips_prefill_bit_identically() {
        let (cfg, w) = tiny("llama-t");
        let system: Vec<u8> = (0..8).map(|t| (t * 23 + 5) as u8).collect(); // 2 full pages
        let mut p1 = system.clone();
        p1.extend([70, 71]);
        let mut p2 = system.clone();
        p2.extend([90, 91, 92]);
        let reqs = vec![
            (p1, 4, SampleConfig { temperature: 0.8, top_k: 10, seed: 21 }),
            (p2, 5, SampleConfig { temperature: 0.8, top_k: 10, seed: 22 }),
        ];
        let expect = reference(&cfg, &w, &reqs);
        // max_batch 1 serializes the two requests, so the first has
        // registered its prompt pages before the second is admitted.
        let base = GenConfig {
            max_batch: 1,
            pages: 8,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: true,
            workers: 1,
        };
        let (got, metrics) = run_server(&cfg, &w, &base, reqs.clone());
        assert_eq!(got, expect, "shared-prefix output must equal sequential");
        // Request 2's first 8 positions came from the trie: its prefill fed
        // only the 3-token tail (plus request 1's full 10 rows).
        assert_eq!(metrics.prefix_hit_tokens, 8);
        assert_eq!(metrics.prefill_rows, 10 + 3);
        assert!(metrics.prefix_hit_rate() > 0.0);
        // And sharing must be output-invariant.
        let off = GenConfig { prefix_share: false, ..base };
        let (got_off, m_off) = run_server(&cfg, &w, &off, reqs);
        assert_eq!(got_off, expect);
        assert_eq!(m_off.prefix_hit_tokens, 0);
        assert_eq!(m_off.prefill_rows, 10 + 11);
    }

    /// A prompt FULLY covered by shared pages (length an exact multiple of
    /// the page size) takes the replay path — no prefill rows at all — and
    /// still matches sequential generate.
    #[test]
    fn serve_full_prefix_cover_replays_last_position() {
        let (cfg, w) = tiny("opt-t");
        let prompt: Vec<u8> = (0..8).map(|t| (t * 31 + 9) as u8).collect(); // exactly 2 pages
        let reqs = vec![
            (prompt.clone(), 3, SampleConfig { temperature: 0.6, top_k: 8, seed: 31 }),
            (prompt.clone(), 4, SampleConfig { temperature: 0.6, top_k: 8, seed: 32 }),
        ];
        let expect = reference(&cfg, &w, &reqs);
        let gen = GenConfig {
            max_batch: 1,
            pages: 8,
            page_size: 4,
            prefill_chunk: 0,
            prefix_share: true,
            workers: 1,
        };
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(got, expect);
        assert_eq!(metrics.prefix_hit_tokens, 8, "request 2's whole prompt was cached");
        assert_eq!(metrics.prefill_rows, 8, "only request 1 prefilled");
    }

    /// A pool too small for both requests' worst case forces preemption:
    /// the younger request is evicted mid-flight, resumes after the older
    /// finishes, and both outputs stay bit-identical to sequential runs.
    #[test]
    fn serve_preemption_resumes_bit_identically() {
        let (cfg, w) = tiny("llama-t");
        // Each request needs 3 + 3 - 1 = 5 rows → 3 pages of 2; the pool
        // holds exactly 3 pages, so both can never be resident at full
        // length simultaneously.
        let gen = GenConfig {
            max_batch: 2,
            pages: 3,
            page_size: 2,
            prefill_chunk: 0,
            prefix_share: false,
            workers: 1,
        };
        let reqs = vec![
            (vec![11, 12, 13], 3, SampleConfig { temperature: 0.9, top_k: 6, seed: 41 }),
            (vec![21, 22, 23], 3, SampleConfig { temperature: 0.9, top_k: 6, seed: 42 }),
        ];
        let expect = reference(&cfg, &w, &reqs);
        let (got, metrics) = run_server(&cfg, &w, &gen, reqs);
        assert_eq!(got, expect, "preempted + resumed output must be bit-identical");
        assert_eq!(metrics.completed, 2);
        assert!(metrics.preemptions >= 1, "this pool must have preempted");
    }

    #[test]
    fn serve_cancelled_client_frees_pool_for_queued_request() {
        let (cfg, w) = tiny("llama-t");
        // One active slot, two requests: the first client hangs up
        // immediately, so the second only runs if cancellation frees room.
        let gen = GenConfig {
            max_batch: 1,
            pages: 16,
            page_size: 2,
            prefill_chunk: 0,
            prefix_share: true,
            workers: 1,
        };
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 5 };
        let (tx, rx) = channel();
        let (s1, r1) = super::super::stream::stream_channel();
        drop(r1); // client 1 gone before serving starts
        tx.send(GenRequest {
            id: 0, prompt: vec![3, 4], max_new: 20, sample: sc, stream: s1,
            enqueued: Instant::now(),
        })
        .unwrap();
        let (s2, r2) = super::super::stream::stream_channel();
        tx.send(GenRequest {
            id: 1, prompt: vec![9, 8, 7], max_new: 5, sample: sc, stream: s2,
            enqueued: Instant::now(),
        })
        .unwrap();
        drop(tx);
        let metrics = serve_generation(&cfg, &w, &NoOverride, &gen, rx).unwrap();
        assert_eq!(metrics.cancelled, 1);
        assert_eq!(metrics.completed, 2); // cancelled + completed both retire
        let (tokens, done) = collect_stream(&r2);
        let expect = generate(&cfg, &w, &NoOverride, &[9, 8, 7], 5, sc).unwrap();
        assert_eq!(tokens, expect);
        assert_eq!(done.unwrap().finish, FinishReason::Completed);
    }
}
