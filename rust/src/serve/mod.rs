//! Continuous-batching generation serving — the deployment subsystem the
//! paper motivates: many concurrent *generation* requests decoded together
//! over a compressed (or dense) model.
//!
//! The scoring server ([`crate::coordinator::server`]) batches whole token
//! windows; generation cannot be batched that way because requests arrive,
//! prefill, decode, and finish on their own schedules.  This module batches
//! at the **step** level instead (Orca-style continuous batching): every
//! active sequence contributes exactly one token row per decode step, and
//! the scheduler admits queued requests into free KV slots *between* steps
//! — prefilling arrivals token-by-token alongside in-flight decodes, never
//! stalling them.
//!
//! * [`kv_pool`]  — slotted KV storage: fixed-capacity per-slot K/V rows,
//!   O(1) acquire/release through a free list, zero allocation per step.
//! * [`step`]     — [`step::decode_step_batched`]: stacks the B active rows
//!   and routes every projection through the tiled GEMM kernel
//!   ([`crate::linalg::gemm`]) — one GEMM per weight instead of B matvecs —
//!   while staying **bit-identical per request** to the sequential
//!   [`crate::model::generate::decode_step`] at every batch size and
//!   worker count.
//! * [`batcher`]  — [`batcher::serve_generation`]: the scheduler loop that
//!   owns the pool; producers fan requests in over an mpsc channel from any
//!   number of threads.
//! * [`stream`]   — per-request streaming delivery: each generated token is
//!   sent over the request's own channel as it is produced, with a final
//!   [`stream::StreamEvent::Done`] carrying latency stats.
//!
//! Determinism contract: a request's output depends only on
//! `(weights, overrides, prompt, SampleConfig)` — per-request seeded RNGs
//! and the bit-identical batched step make the served tokens equal to a
//! fresh single-request [`crate::model::generate::generate`] run no matter
//! which neighbors shared its batches (pinned by the parity tests in
//! [`batcher`] and [`step`]).

pub mod batcher;
pub mod kv_pool;
pub mod step;
pub mod stream;

#[cfg(test)]
pub(crate) mod test_util {
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;

    /// The serve parity fixture — delegates to the crate-wide
    /// [`crate::bench::tiny_model`] so the unit-test parity suites and
    /// `perf_serve`'s parity smoke always exercise the same model shape.
    pub fn tiny(name: &str, seed: u64) -> (ModelConfig, Weights) {
        crate::bench::tiny_model(name, seed)
    }
}

pub use batcher::{serve_generation, GenConfig, GenRequest};
pub use kv_pool::KvPool;
pub use step::{decode_step_batched, StepRow};
pub use stream::{collect_stream, stream_channel, DoneStats, FinishReason, StreamEvent, TokenStream};
