//! Continuous-batching generation serving — the deployment subsystem the
//! paper motivates: many concurrent *generation* requests decoded together
//! over a compressed (or dense) model.
//!
//! The scoring server ([`crate::coordinator::server`]) batches whole token
//! windows; generation cannot be batched that way because requests arrive,
//! prefill, decode, and finish on their own schedules.  This module batches
//! at the **step** level instead (Orca-style continuous batching): every
//! active sequence contributes rows per decode step (one for decodes, a
//! bounded chunk for prefills), and the scheduler admits queued requests
//! *between* steps — prefilling arrivals alongside in-flight decodes,
//! never stalling them.
//!
//! * [`kv_pool`]  — **paged** KV storage (vLLM-style): fixed-size pages
//!   from one free list, per-sequence page tables, refcounted sharing with
//!   copy-on-write, fault-in growth — no per-request worst-case
//!   reservation, zero float allocation per step.
//! * [`prefix`]   — radix trie over full `page_size`-token prompt chunks:
//!   requests sharing a prompt prefix alias the same already-populated
//!   pages and skip that prefill entirely (LRU-evicted under pressure).
//! * [`step`]     — [`step::decode_step_batched`]: stacks the planned rows
//!   and routes every projection through the tiled GEMM kernel
//!   ([`crate::linalg::gemm`]) — one GEMM per weight instead of B matvecs —
//!   attending over page-indexed history while staying **bit-identical per
//!   request** to the sequential [`crate::model::generate::decode_step`]
//!   at every batch size, page size, chunk split, and worker count.
//! * [`batcher`]  — [`batcher::serve_generation`]: the scheduler loop that
//!   owns the pool and trie; ranks work by QoS (priority, then deadline,
//!   then arrival — pure FIFO with default fields), plans chunked
//!   prefills, resolves pool exhaustion by trie eviction then preemption
//!   (least-urgent victim re-queues and later resumes exactly), enforces
//!   deadlines and the bounded-queue overload policy, isolates per-request
//!   step failures behind a watchdog, and streams tokens as they are
//!   sampled.  Producers fan requests in over an mpsc channel from any
//!   number of threads.
//! * [`stream`]   — per-request streaming delivery: each generated token is
//!   sent over the request's own channel as it is produced, with a final
//!   [`stream::StreamEvent::Done`] carrying latency stats and the
//!   terminal [`stream::FinishReason`].
//! * [`chaos`]    — seeded, stateless fault injection (step faults,
//!   simulated allocation failures) wired into the scheduler loop; the
//!   chaos fuzz grid in `fuzz` pins that surviving requests stay
//!   bit-exact and every casualty gets exactly one correct terminal.
//!
//! Determinism contract: a request's output depends only on
//! `(weights, overrides, prompt, SampleConfig)` — per-request seeded RNGs
//! and the bit-identical batched step make the served tokens equal to a
//! fresh single-request [`crate::model::generate::generate`] run no matter
//! which neighbors shared its batches, which pages its KV landed in,
//! whether its prefix came from the trie, or how often it was preempted
//! (pinned by the parity tests in [`batcher`] and [`step`], and by the
//! randomized schedule fuzz harness in `fuzz`).
//!
//! The contract extends to the **compressed KV cache**
//! ([`crate::model::kvc::KvCompression`], `--kv-ratio`): pages store
//! rank-wide latents, the step fuses the down-projection into the K/V
//! GEMM and up-projects at attention time, and the served bits equal a
//! single-request [`crate::model::generate::generate_kv`] run under the
//! same factors — the fuzz grid sweeps kv-ratio alongside page size,
//! workers, preemption, and chaos.

pub mod batcher;
pub mod chaos;
pub mod kv_pool;
pub mod prefix;
pub mod step;
pub mod stream;

#[cfg(test)]
mod fuzz;

#[cfg(test)]
pub(crate) mod test_util {
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;

    /// The serve parity fixture — delegates to the crate-wide
    /// [`crate::bench::tiny_model`] so the unit-test parity suites and
    /// `perf_serve`'s parity smoke always exercise the same model shape.
    pub fn tiny(name: &str, seed: u64) -> (ModelConfig, Weights) {
        crate::bench::tiny_model(name, seed)
    }
}

pub use batcher::{serve_generation, serve_generation_kv, ClockMode, GenConfig, GenRequest};
pub use chaos::ChaosConfig;
pub use kv_pool::KvPool;
pub use prefix::PrefixTrie;
pub use step::{decode_step_batched, decode_step_batched_kv, StepRow};
pub use stream::{collect_stream, stream_channel, DoneStats, FinishReason, StreamEvent, TokenStream};
