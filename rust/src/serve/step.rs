//! The batched decode step: B token rows (across sequences), one GEMM per
//! projection over the stacked rows, K/V history read through paged views.
//!
//! This is where the kernel layer finally earns decode throughput: the
//! sequential [`decode_step`](crate::model::generate::decode_step) runs
//! each of the ~7 projections per layer as a 1-row GEMM (a matvec), so a
//! batch of B rows costs `B × layers × 7` matvecs.  Stacking the B rows
//! turns that into `layers × 7` GEMMs of height B — same flops, far better
//! operand reuse through [`crate::linalg::gemm`]'s packed panels.
//!
//! Two row shapes beyond plain one-token decode:
//!
//! * **Chunked prefill** — several consecutive-position rows of the SAME
//!   sequence in one step.  Sound because each layer pushes every row's K/V
//!   before the per-row attention loop runs, so a later row of the chunk
//!   attends over its earlier rows' just-written history exactly as the
//!   sequential path would, and the GEMMs are row-independent.
//! * **Replay rows** ([`StepRow::write_kv`]` == false`) — re-feed an
//!   already-cached position to recompute its logits without writing KV.
//!   Used when prefix sharing covers a whole prompt: the KV rows exist
//!   (written by the request that populated the shared pages), only the
//!   last prompt position's logits are missing.  Bit-sound because the KV
//!   row at position `p` is a deterministic function of token ids `0..=p`
//!   through this exact code path — the stored bits equal what this row
//!   would have written.
//!
//! **Bit-identity contract.**  Per request, the batched step reproduces the
//! sequential step bit-for-bit at every batch size, chunking, page size,
//! and worker count:
//!
//! * the GEMM's per-element accumulation order is ascending-k within K
//!   blocks regardless of the row count, row position, or worker count, so
//!   row r of `[B, d] @ W` equals the 1-row product of that row alone;
//! * everything that is *not* a GEMM (norms, RoPE, attention over the
//!   sequence's own paged history, activation nonlinearities) runs per row
//!   through the same crate-private helpers the sequential path calls
//!   (`rmsnorm_row`, `rope_row`, `attend_row`, …);
//! * paged history is presented to `attend_row` as a contiguous span: a
//!   one-page span is borrowed in place, a multi-page span is gathered
//!   page-by-page into a reused scratch buffer.  Either way the slice holds
//!   the same bits in the same order as the sequential cache, and the
//!   window bounds are rebased (`lo − base`, `t_now − base`) so the
//!   float-op order inside `attend_row` is untouched;
//! * compressed overrides ([`LinearOverride`]) route through the same
//!   factor GEMMs, which batch the same way.
//!
//! The parity tests at the bottom pin logits bit-equality against
//! `decode_step`, including staggered joins, multi-page chunks, and replay.
//!
//! **Re-execution contract (the batcher's watchdog relies on this).**  A
//! step attempt that dies partway — a panic in the model math or an
//! injected chaos fault — leaves the pool in a state where re-running any
//! subset of the same rows is bit-identical to a clean first run:
//!
//! * committed lengths are untouched until the very END of the step
//!   (`set_len` runs once per sequence after every layer finished), so a
//!   failed attempt never advances what the planner sees;
//! * `KvPool::prepare` is idempotent for already-tabled positions, and
//! * `push_row` deterministically overwrites its slice, so K/V bytes a
//!   dead attempt half-wrote are simply rewritten with the same bits.
//!
//! The watchdog in [`super::batcher`] uses this to re-execute each
//! sequence's rows alone after a failed batched attempt; sequences only
//! ever *read* pages they share (written positions are CoW'd private by
//! `prepare`), so per-sequence re-runs see the same history bytes the
//! batched run would have.  Pinned by `step_reexecution_is_idempotent`.

use super::kv_pool::{KvPool, SeqId};
use crate::linalg::gemm;
use crate::model::config::{Family, ModelConfig};
use crate::model::forward::{matmul_f32, LinearOverride};
use crate::model::generate::{attend_row, layernorm_row, rmsnorm_row, rope_row};
use crate::model::kvc::KvCompression;
use crate::model::weights::Weights;
use anyhow::Result;

/// Normalize every d-wide row of `h` in place — RMSNorm when `bias` is
/// `None`, OPT LayerNorm otherwise.  The caller fetches the norm weights
/// once per layer; the per-row math is the sequential path's helpers.
fn norm_rows(h: &mut [f32], d: usize, w: &[f32], bias: Option<&[f32]>) {
    for hr in h.chunks_mut(d) {
        match bias {
            Some(bias) => layernorm_row(hr, w, bias),
            None => rmsnorm_row(hr, w),
        }
    }
}

/// One token row of a decode step.
#[derive(Clone, Copy, Debug)]
pub struct StepRow {
    /// Pool sequence this row belongs to.  Rows of the same sequence must
    /// be adjacent in the batch with contiguously ascending positions
    /// (a prefill chunk).
    pub seq: SeqId,
    /// Token fed this step (prompt token while prefilling, last sampled
    /// token while decoding).
    pub token: u8,
    /// Position of `token` in the sequence (0-based).
    pub pos: usize,
    /// Will the caller read this row's logits?  `false` while prefilling
    /// (all but the last prompt token): the row still writes its K/V, but
    /// the lm_head GEMM — the dominant per-step cost at real vocab sizes —
    /// skips it and its logits row is returned zeroed.
    pub needs_logits: bool,
    /// Write this row's K/V into the pool (`pos == pool.len(seq)` plus the
    /// chunk offset)?  `false` replays an already-cached position
    /// (`pos + 1 == pool.len(seq)`) to recover its logits after a full
    /// prefix-share — a replay row stands alone for its sequence.
    pub write_kv: bool,
}

/// One decode step over `rows`: feed each row's token at its own position,
/// write K/V for `write_kv` rows, and return the stacked logits
/// `[rows.len(), vocab]` (row order = `rows` order; rows with
/// `needs_logits == false` are zeroed — their lm_head product is skipped).
///
/// `workers` is the GEMM thread share for the stacked products
/// (0 = all cores); results are bit-identical for every value.  The caller
/// (the batcher) must have made every written position's page writable via
/// [`KvPool::prepare`] — allocation policy (fault-in, CoW, eviction,
/// preemption) lives there, not in the hot step.
///
/// LOCKSTEP WARNING: this is the batched twin of the sequential
/// [`decode_step`](crate::model::generate::decode_step) — the transformer
/// math here must mirror that function operation-for-operation (the
/// layering rule keeps it out of `model/`, which cannot import the L3 KV
/// pool).  Any model change must be made in BOTH, and the ci.sh parity
/// smokes (`cargo test -q serve`, `perf_serve -- parity`) pin the
/// bit-identity.
pub fn decode_step_batched(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    pool: &mut KvPool,
    rows: &[StepRow],
    workers: usize,
) -> Result<Vec<f32>> {
    decode_step_batched_kv(cfg, weights, overrides, None, pool, rows, workers)
}

/// [`decode_step_batched`] with optional KV-cache compression
/// ([`KvCompression`]): a compressed layer's K/V projection GEMM is
/// REPLACED by the fused down-projection (one stacked GEMM of width `r`
/// instead of `d_model` — [`crate::model::kvc::KvProj::project`]), the
/// pool pages store the rank-wide latents **pre-RoPE**, and each row's
/// attention up-projects its gathered latent span
/// ([`crate::model::kvc::KvProj::reconstruct`], one extra small GEMM) then
/// RoPE-rotates the K rows at their absolute positions before the
/// unchanged `attend_row`.  `pool` must have been built with the same
/// compression ([`KvPool::with_kvc`]).
///
/// The bit-identity contract extends through compression: both factor
/// GEMMs are row-independent at every worker count, so a latent written
/// once reconstructs to the same bits whether this batched path
/// up-projects a per-page span or the sequential oracle
/// ([`crate::model::generate::decode_step_kv`]) up-projects the full
/// history — pinned per family/page-size/worker-count by the tests below
/// and the serve fuzz battery.  Identity layers (and `kvc` `None`) take
/// literally the uncompressed code path.
pub fn decode_step_batched_kv(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    kvc: Option<&KvCompression>,
    pool: &mut KvPool,
    rows: &[StepRow],
    workers: usize,
) -> Result<Vec<f32>> {
    let b = rows.len();
    if b == 0 {
        return Ok(Vec::new());
    }
    #[cfg(debug_assertions)]
    {
        let mut seen: Vec<SeqId> = Vec::new();
        let mut r = 0;
        while r < rows.len() {
            let seq = rows[r].seq;
            debug_assert!(
                !seen.contains(&seq),
                "rows of one sequence must be adjacent in the batch"
            );
            seen.push(seq);
            if !rows[r].write_kv {
                debug_assert_eq!(
                    rows[r].pos + 1,
                    pool.len(seq),
                    "replay row must re-feed the last committed position"
                );
                r += 1;
                debug_assert!(
                    r >= rows.len() || rows[r].seq != seq,
                    "a replay row stands alone for its sequence"
                );
                continue;
            }
            let mut pos = pool.len(seq);
            while r < rows.len() && rows[r].seq == seq {
                debug_assert!(
                    rows[r].write_kv,
                    "write and replay rows cannot mix within one sequence"
                );
                debug_assert_eq!(
                    rows[r].pos, pos,
                    "chunk positions advance contiguously from the committed length"
                );
                pos += 1;
                r += 1;
            }
        }
    }
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let page = pool.page_size();
    let _gemm_threads = gemm::scoped_workers(if workers == 0 {
        crate::util::threads::default_workers()
    } else {
        workers
    });

    let tok_emb = weights.get("tok_emb")?;
    let mut x = vec![0.0f32; b * d];
    for (r, row) in rows.iter().enumerate() {
        x[r * d..(r + 1) * d].copy_from_slice(tok_emb.row(row.token as usize));
    }
    if cfg.family == Family::Opt {
        let pos_emb = weights.get("pos_emb")?;
        for (r, row) in rows.iter().enumerate() {
            for j in 0..d {
                x[r * d + j] += pos_emb.at2(row.pos.min(cfg.max_seq - 1), j);
            }
        }
    }
    // One GEMM per weight over the stacked rows (or the override's factor
    // GEMMs — CompressedLayer::apply batches identically).
    let lin = |name: &str, h: &[f32], in_dim: usize| -> Result<Vec<f32>> {
        if let Some(y) = overrides.apply(name, h, b, in_dim) {
            return Ok(y);
        }
        Ok(matmul_f32(h, b, in_dim, weights.get(name)?))
    };
    // Scratch for multi-page history gathers, reused across rows and layers.
    let mut k_buf: Vec<f32> = Vec::new();
    let mut v_buf: Vec<f32> = Vec::new();
    for i in 0..cfg.n_layers {
        let mut h = x.clone();
        let nw = &weights.get(&format!("blocks.{i}.attn_norm.w"))?.data;
        let nb = match cfg.family {
            Family::Opt => Some(weights.get(&format!("blocks.{i}.attn_norm.b"))?.data.as_slice()),
            _ => None,
        };
        norm_rows(&mut h, d, nw, nb);
        let kp = kvc.and_then(|c| c.layers.get(i)).and_then(|l| l.k.as_ref());
        let vp = kvc.and_then(|c| c.layers.get(i)).and_then(|l| l.v.as_ref());
        let (wk_i, wv_i) = (kp.map_or(d, |p| p.rank), vp.map_or(d, |p| p.rank));
        debug_assert_eq!(pool.width_k(i), wk_i, "pool built with a different compression");
        debug_assert_eq!(pool.width_v(i), wv_i, "pool built with a different compression");
        let mut q = lin(&format!("blocks.{i}.attn.wq"), &h, d)?;
        // Fused down-projection: for a compressed layer the latent GEMM
        // replaces the dense K/V projection (and any weight-compression
        // override of it); latents are stored pre-RoPE.
        let mut k = match kp {
            Some(p) => p.project(&h, b),
            None => lin(&format!("blocks.{i}.attn.wk"), &h, d)?,
        };
        let v = match vp {
            Some(p) => p.project(&h, b),
            None => lin(&format!("blocks.{i}.attn.wv"), &h, d)?,
        };
        // Push EVERY write row's K/V before any attention: a later chunk
        // row must see its predecessors' history (replay rows skip the
        // write — their position's bits are already in a shared page).
        for (r, row) in rows.iter().enumerate() {
            if cfg.family.uses_rope() {
                rope_row(&mut q[r * d..(r + 1) * d], heads, hd, row.pos);
            }
            if row.write_kv {
                if cfg.family.uses_rope() && kp.is_none() {
                    rope_row(&mut k[r * d..(r + 1) * d], heads, hd, row.pos);
                }
                pool.push_row(
                    row.seq,
                    i,
                    row.pos,
                    &k[r * wk_i..(r + 1) * wk_i],
                    &v[r * wv_i..(r + 1) * wv_i],
                );
            }
        }
        // Attention stays per row: each sequence attends over its own paged
        // history (identical float-op order to the sequential path via
        // attend_row; `lo`/`t_now` are rebased onto the presented span).
        // Compressed layers up-project the span's latents first and RoPE
        // the K rows at their absolute positions — row-independent GEMMs,
        // so the reconstructed bits match the sequential oracle's
        // full-history reconstruction row for row.
        let mut att_sp = crate::obs::span("serve.attention");
        if att_sp.is_recording() {
            att_sp.arg_u64("layer", i as u64).arg_u64("rows", b as u64);
        }
        let mut att = vec![0.0f32; b * d];
        for (r, row) in rows.iter().enumerate() {
            let t_now = row.pos + 1;
            let lo = if cfg.window > 0 { t_now.saturating_sub(cfg.window) } else { 0 };
            let base = (lo / page) * page;
            let q_row = &q[r * d..(r + 1) * d];
            let att_row = &mut att[r * d..(r + 1) * d];
            let (kh_raw, vh_raw): (&[f32], &[f32]) =
                match pool.hist_slices(row.seq, i, base, t_now) {
                    Some((kh, vh)) => (kh, vh),
                    None => {
                        pool.gather_hist(row.seq, i, base, t_now, &mut k_buf, &mut v_buf);
                        (&k_buf, &v_buf)
                    }
                };
            let span = t_now - base;
            let k_store: Vec<f32>;
            let v_store: Vec<f32>;
            let kh: &[f32] = match kp {
                Some(p) => {
                    debug_assert_eq!(p.d_out, d, "K up-projection must restore d_model");
                    let mut full = p.reconstruct(kh_raw, span);
                    if cfg.family.uses_rope() {
                        for (j, krow) in full.chunks_mut(d).enumerate() {
                            rope_row(krow, heads, hd, base + j);
                        }
                    }
                    k_store = full;
                    &k_store
                }
                None => kh_raw,
            };
            let vh: &[f32] = match vp {
                Some(p) => {
                    debug_assert_eq!(p.d_out, d, "V up-projection must restore d_model");
                    v_store = p.reconstruct(vh_raw, span);
                    &v_store
                }
                None => vh_raw,
            };
            attend_row(q_row, kh, vh, heads, hd, scale, lo - base, t_now - base, att_row);
        }
        drop(att_sp);
        let o = lin(&format!("blocks.{i}.attn.wo"), &att, d)?;
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let mut h = x.clone();
        let nw = &weights.get(&format!("blocks.{i}.mlp_norm.w"))?.data;
        let nb = match cfg.family {
            Family::Opt => Some(weights.get(&format!("blocks.{i}.mlp_norm.b"))?.data.as_slice()),
            _ => None,
        };
        norm_rows(&mut h, d, nw, nb);
        let m = if cfg.family == Family::Opt {
            let mut u = lin(&format!("blocks.{i}.mlp.fc1"), &h, d)?;
            for uv in u.iter_mut() {
                *uv = uv.max(0.0);
            }
            lin(&format!("blocks.{i}.mlp.fc2"), &u, cfg.d_ff)?
        } else {
            let mut g = lin(&format!("blocks.{i}.mlp.w_gate"), &h, d)?;
            let u = lin(&format!("blocks.{i}.mlp.w_up"), &h, d)?;
            for (gv, uv) in g.iter_mut().zip(&u) {
                let sg = *gv / (1.0 + (-*gv).exp());
                *gv = sg * uv;
            }
            lin(&format!("blocks.{i}.mlp.w_down"), &g, cfg.d_ff)?
        };
        for (xv, mv) in x.iter_mut().zip(&m) {
            *xv += mv;
        }
    }
    let nw = &weights.get("final_norm.w")?.data;
    let nb = match cfg.family {
        Family::Opt => Some(weights.get("final_norm.b")?.data.as_slice()),
        _ => None,
    };
    norm_rows(&mut x, d, nw, nb);
    // Commit once per sequence with the chunk's FINAL length — an
    // intermediate set_len would truncate (and free!) the later chunk
    // rows' already-written pages.
    for (idx, row) in rows.iter().enumerate() {
        if !row.write_kv {
            continue;
        }
        let last_of_seq = rows.get(idx + 1).map_or(true, |n| n.seq != row.seq);
        if last_of_seq {
            pool.set_len(row.seq, row.pos + 1);
        }
    }
    // lm_head only over the rows whose logits the caller reads — prefill
    // rows' logits are discarded, and at a real vocab the lm_head GEMM
    // dominates the step.  The GEMM is row-independent, so the computed
    // rows are bit-identical to the all-rows product; skipped rows come
    // back zeroed.
    let lm_head = weights.get("lm_head")?;
    if rows.iter().all(|row| row.needs_logits) {
        return Ok(matmul_f32(&x, b, d, lm_head));
    }
    let need: Vec<usize> = (0..b).filter(|&r| rows[r].needs_logits).collect();
    let vocab = cfg.vocab;
    let mut logits = vec![0.0f32; b * vocab];
    if !need.is_empty() {
        let mut xs = vec![0.0f32; need.len() * d];
        for (j, &r) in need.iter().enumerate() {
            xs[j * d..(j + 1) * d].copy_from_slice(&x[r * d..(r + 1) * d]);
        }
        let sub = matmul_f32(&xs, need.len(), d, lm_head);
        for (j, &r) in need.iter().enumerate() {
            logits[r * vocab..(r + 1) * vocab].copy_from_slice(&sub[j * vocab..(j + 1) * vocab]);
        }
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::NoOverride;
    use crate::model::generate::{decode_step, KvCache};

    fn tiny(name: &str) -> (ModelConfig, Weights) {
        crate::serve::test_util::tiny(name, 31)
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
        }
    }

    /// Fault in the pages every write row of `rows` needs (the batcher's
    /// job in production).
    fn prep(pool: &mut KvPool, rows: &[StepRow]) {
        for row in rows {
            if row.write_kv {
                pool.prepare(row.seq, row.pos).expect("test pool sized to fit");
            }
        }
    }

    fn write_row(seq: usize, token: u8, pos: usize, needs_logits: bool) -> StepRow {
        StepRow { seq, token, pos, needs_logits, write_kv: true }
    }

    /// Lockstep batched decode vs B independent sequential decoders must be
    /// bit-identical per row, for every family, page size, and worker count.
    #[test]
    fn serve_batched_step_bit_identical_lockstep() {
        for name in ["llama-t", "opt-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            for &page_size in &[1usize, 4] {
                for &workers in &[1usize, 4] {
                    let b = 3usize;
                    let mut pool = KvPool::new(&cfg, 8usize.div_ceil(page_size) * b, page_size);
                    let seqs_id: Vec<usize> = (0..b).map(|_| pool.new_seq()).collect();
                    let mut caches: Vec<KvCache> = (0..b).map(|_| KvCache::new(&cfg)).collect();
                    let seqs: Vec<Vec<u8>> = (0..b)
                        .map(|s| (0..8).map(|t| ((s * 91 + t * 37) % 251) as u8).collect())
                        .collect();
                    for pos in 0..8 {
                        let rows: Vec<StepRow> = (0..b)
                            .map(|s| write_row(seqs_id[s], seqs[s][pos], pos, true))
                            .collect();
                        prep(&mut pool, &rows);
                        let batched =
                            decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, workers)
                                .unwrap();
                        for s in 0..b {
                            let seq = decode_step(
                                &cfg, &w, &NoOverride, &mut caches[s], seqs[s][pos], pos,
                            )
                            .unwrap();
                            assert_bits_eq(
                                &batched[s * cfg.vocab..(s + 1) * cfg.vocab],
                                &seq,
                                &format!("{name} ps={page_size} w={workers} seq {s} pos {pos}"),
                            );
                        }
                    }
                }
            }
        }
    }

    /// The watchdog's recovery path: after a dead batched attempt (pages
    /// prepared, nothing committed), re-executing the same planned rows
    /// one sequence at a time is bit-identical to the clean batched call —
    /// and the pool state both leave behind is indistinguishable.
    #[test]
    fn step_reexecution_is_idempotent() {
        let (cfg, w) = tiny("llama-t");
        let hist: [Vec<u8>; 2] = [
            (0..4).map(|t| (t * 61 + 3) as u8).collect(),
            (0..4).map(|t| (t * 17 + 9) as u8).collect(),
        ];
        // Two pools with identical committed histories.
        let mut pools = Vec::new();
        let mut ids = Vec::new();
        for _ in 0..2 {
            let mut pool = KvPool::new(&cfg, 16, 2);
            let sid: Vec<usize> = (0..2).map(|_| pool.new_seq()).collect();
            for pos in 0..4 {
                let rows: Vec<StepRow> = (0..2)
                    .map(|s| write_row(sid[s], hist[s][pos], pos, false))
                    .collect();
                prep(&mut pool, &rows);
                decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
            }
            pools.push(pool);
            ids.push(sid);
        }
        // The step under test: seq 0 feeds a 2-row chunk, seq 1 one decode
        // row.
        let plan = |sid: &[usize]| {
            vec![
                write_row(sid[0], 101, 4, false),
                write_row(sid[0], 102, 5, true),
                write_row(sid[1], 103, 4, true),
            ]
        };
        // Pool 0: the clean batched attempt.
        let rows = plan(&ids[0]);
        prep(&mut pools[0], &rows);
        let clean = decode_step_batched(&cfg, &w, &NoOverride, &mut pools[0], &rows, 1).unwrap();
        // Pool 1: the dead attempt prepared its pages (twice — prepare is
        // idempotent), committed nothing; the watchdog then re-runs one
        // sequence at a time.
        let rows = plan(&ids[1]);
        prep(&mut pools[1], &rows);
        prep(&mut pools[1], &rows);
        let g0 = decode_step_batched(&cfg, &w, &NoOverride, &mut pools[1], &rows[0..2], 1).unwrap();
        let g1 = decode_step_batched(&cfg, &w, &NoOverride, &mut pools[1], &rows[2..3], 1).unwrap();
        let vocab = cfg.vocab;
        assert_bits_eq(&g0[vocab..2 * vocab], &clean[vocab..2 * vocab], "seq 0 recovered logits");
        assert_bits_eq(&g1, &clean[2 * vocab..], "seq 1 recovered logits");
        // Both pools committed the same lengths...
        for (pool, sid) in pools.iter().zip(&ids) {
            assert_eq!(pool.len(sid[0]), 6);
            assert_eq!(pool.len(sid[1]), 5);
        }
        // ...and the NEXT step over each pool produces identical bits.
        let mut after = Vec::new();
        for (pool, sid) in pools.iter_mut().zip(&ids) {
            let rows = vec![
                write_row(sid[0], 111, 6, true),
                write_row(sid[1], 112, 5, true),
            ];
            prep(pool, &rows);
            after.push(decode_step_batched(&cfg, &w, &NoOverride, pool, &rows, 1).unwrap());
        }
        assert_bits_eq(&after[0], &after[1], "post-recovery step");
    }

    /// A sequence joining mid-stream (staggered positions within one batch)
    /// must match a fresh sequential run bit-for-bit.
    #[test]
    fn serve_batched_step_bit_identical_staggered_join() {
        let (cfg, w) = tiny("llama-t");
        let mut pool = KvPool::new(&cfg, 8, 4);
        let sa = pool.new_seq();
        let seq_a: Vec<u8> = (0..9).map(|t| (t * 53 % 256) as u8).collect();
        let seq_b: Vec<u8> = (0..6).map(|t| (t * 29 + 7) as u8).collect();
        let mut cache_a = KvCache::new(&cfg);
        let mut cache_b = KvCache::new(&cfg);
        // A runs alone for 3 steps.
        for pos in 0..3 {
            let rows = [write_row(sa, seq_a[pos], pos, true)];
            prep(&mut pool, &rows);
            let batched =
                decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
            let seq = decode_step(&cfg, &w, &NoOverride, &mut cache_a, seq_a[pos], pos).unwrap();
            assert_bits_eq(&batched, &seq, &format!("solo A pos {pos}"));
        }
        // B joins at step 3: batch rows now at staggered positions.
        let sb = pool.new_seq();
        for t in 0..6 {
            let pos_a = 3 + t;
            let rows = [
                write_row(sa, seq_a[pos_a], pos_a, true),
                write_row(sb, seq_b[t], t, true),
            ];
            prep(&mut pool, &rows);
            let batched =
                decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 4).unwrap();
            let ref_a =
                decode_step(&cfg, &w, &NoOverride, &mut cache_a, seq_a[pos_a], pos_a).unwrap();
            let ref_b = decode_step(&cfg, &w, &NoOverride, &mut cache_b, seq_b[t], t).unwrap();
            let v = cfg.vocab;
            assert_bits_eq(&batched[..v], &ref_a, &format!("joined A step {t}"));
            assert_bits_eq(&batched[v..2 * v], &ref_b, &format!("joined B step {t}"));
        }
        assert_eq!(pool.len(sa), 9);
        assert_eq!(pool.len(sb), 6);
    }

    /// A whole prompt fed as ONE multi-row chunk (crossing page boundaries)
    /// must produce the same last-position logits as position-by-position
    /// sequential decode — for every family, including the sliding-window
    /// one (mistral-t, window 4 < prompt length).
    #[test]
    fn serve_batched_step_chunked_prefill_bit_identical() {
        for name in ["llama-t", "opt-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            let prompt: Vec<u8> = (0..7).map(|t| (t * 41 + 3) as u8).collect();
            let mut reference = Vec::new();
            let mut cache = KvCache::new(&cfg);
            for (pos, &t) in prompt.iter().enumerate() {
                reference = decode_step(&cfg, &w, &NoOverride, &mut cache, t, pos).unwrap();
            }
            for &page_size in &[1usize, 2, 16] {
                let mut pool = KvPool::new(&cfg, prompt.len().div_ceil(page_size), page_size);
                let s = pool.new_seq();
                let rows: Vec<StepRow> = prompt
                    .iter()
                    .enumerate()
                    .map(|(pos, &t)| write_row(s, t, pos, pos + 1 == prompt.len()))
                    .collect();
                prep(&mut pool, &rows);
                let logits =
                    decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 2).unwrap();
                let v = cfg.vocab;
                assert_bits_eq(
                    &logits[(prompt.len() - 1) * v..],
                    &reference,
                    &format!("{name} ps={page_size} one-chunk prefill"),
                );
                assert_eq!(pool.len(s), prompt.len());
            }
        }
    }

    /// Splitting the same prompt into different chunk sizes must not change
    /// a single bit of the final logits.
    #[test]
    fn serve_batched_step_chunk_split_invariant() {
        let (cfg, w) = tiny("llama-t");
        let prompt: Vec<u8> = (0..9).map(|t| (t * 67 + 11) as u8).collect();
        let run = |chunk: usize| -> Vec<f32> {
            let mut pool = KvPool::new(&cfg, 5, 2);
            let s = pool.new_seq();
            let mut logits = Vec::new();
            let mut pos = 0;
            while pos < prompt.len() {
                let end = (pos + chunk).min(prompt.len());
                let rows: Vec<StepRow> = (pos..end)
                    .map(|p| write_row(s, prompt[p], p, p + 1 == prompt.len()))
                    .collect();
                prep(&mut pool, &rows);
                logits =
                    decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
                pos = end;
            }
            let v = cfg.vocab;
            logits[logits.len() - v..].to_vec()
        };
        let whole = run(prompt.len());
        for &chunk in &[1usize, 2, 4] {
            assert_bits_eq(&run(chunk), &whole, &format!("chunk={chunk}"));
        }
    }

    /// A replay row (write_kv = false) over fully-cached history recovers
    /// the same logits as the write-path step that cached it, and commits
    /// nothing.
    #[test]
    fn serve_batched_step_replay_row_bit_identical() {
        let (cfg, w) = tiny("llama-t");
        let prompt: Vec<u8> = (0..6).map(|t| (t * 19 + 5) as u8).collect();
        let mut pool = KvPool::new(&cfg, 3, 2);
        let s = pool.new_seq();
        let rows: Vec<StepRow> = prompt
            .iter()
            .enumerate()
            .map(|(pos, &t)| write_row(s, t, pos, pos + 1 == prompt.len()))
            .collect();
        prep(&mut pool, &rows);
        let write_logits =
            decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
        let v = cfg.vocab;
        let want = &write_logits[(prompt.len() - 1) * v..];
        let free_before = pool.free_pages();
        // Replay the last prompt position: no prepare, no KV write.
        let replay = [StepRow {
            seq: s,
            token: prompt[prompt.len() - 1],
            pos: prompt.len() - 1,
            needs_logits: true,
            write_kv: false,
        }];
        let got = decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &replay, 1).unwrap();
        assert_bits_eq(&got, want, "replayed logits");
        assert_eq!(pool.len(s), prompt.len(), "replay commits nothing");
        assert_eq!(pool.free_pages(), free_before, "replay allocates nothing");
    }

    /// Replay over pages written by ANOTHER sequence (the prefix-sharing
    /// fork) reproduces the original owner's logits bit-for-bit.
    #[test]
    fn serve_batched_step_replay_over_forked_pages() {
        let (cfg, w) = tiny("llama-t");
        let prompt: Vec<u8> = (0..4).map(|t| (t * 31 + 2) as u8).collect();
        let mut pool = KvPool::new(&cfg, 4, 2);
        let a = pool.new_seq();
        let rows: Vec<StepRow> = prompt
            .iter()
            .enumerate()
            .map(|(pos, &t)| write_row(a, t, pos, pos + 1 == prompt.len()))
            .collect();
        prep(&mut pool, &rows);
        let a_logits = decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
        let v = cfg.vocab;
        // B aliases both of A's (full) pages — its whole prompt is cached.
        let b = pool.fork_seq(&[pool.page_at(a, 0), pool.page_at(a, 1)]);
        let replay = [StepRow {
            seq: b,
            token: prompt[3],
            pos: 3,
            needs_logits: true,
            write_kv: false,
        }];
        let got = decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &replay, 1).unwrap();
        assert_bits_eq(&got, &a_logits[3 * v..], "forked replay logits");
    }

    #[test]
    fn serve_batched_step_skips_prefill_logits() {
        let (cfg, w) = tiny("llama-t");
        let mut pool = KvPool::new(&cfg, 2, 4);
        let s0 = pool.new_seq();
        let s1 = pool.new_seq();
        let rows = [write_row(s0, 9, 0, true), write_row(s1, 17, 0, false)];
        prep(&mut pool, &rows);
        let both = decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
        let v = cfg.vocab;
        // The prefill row's logits come back zeroed, the other row stays
        // bit-identical to a sequential decode of it alone.
        assert!(both[v..2 * v].iter().all(|&x| x == 0.0));
        let mut cache = KvCache::new(&cfg);
        let seq = decode_step(&cfg, &w, &NoOverride, &mut cache, 9, 0).unwrap();
        assert_bits_eq(&both[..v], &seq, "needs_logits row");
        // The skipped row's KV still advanced.
        assert_eq!(pool.len(s1), 1);
    }

    #[test]
    fn serve_batched_step_empty_batch_is_noop() {
        let (cfg, w) = tiny("llama-t");
        let mut pool = KvPool::new(&cfg, 1, 4);
        let out = decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &[], 1).unwrap();
        assert!(out.is_empty());
    }

    // ---- compressed-KV parity ------------------------------------------

    use crate::compress::kv::compress_kv_plain;
    use crate::linalg::rsvd::SvdPolicy;
    use crate::model::generate::decode_step_kv;

    /// Lockstep batched decode with compressed KV latents vs B independent
    /// sequential compressed-KV decoders: bit-identical per row for every
    /// family, page size, and worker count.  The batched path up-projects
    /// per-page latent spans, the oracle the full history — row-independent
    /// GEMMs make the reconstructed bits equal.
    #[test]
    fn kv_compress_batched_step_matches_sequential_oracle() {
        for name in ["llama-t", "opt-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            let kvc = compress_kv_plain(&cfg, &w, 0.5, &SvdPolicy::exact()).unwrap();
            assert!(!kvc.is_identity(), "{name}: ratio 0.5 must compress");
            for &page_size in &[1usize, 4] {
                for &workers in &[1usize, 4] {
                    let b = 3usize;
                    let mut pool = KvPool::with_kvc(
                        &cfg,
                        8usize.div_ceil(page_size) * b,
                        page_size,
                        Some(&kvc),
                    );
                    let seqs_id: Vec<usize> = (0..b).map(|_| pool.new_seq()).collect();
                    let mut caches: Vec<KvCache> = (0..b)
                        .map(|_| KvCache::with_kvc(&cfg, cfg.max_seq, Some(&kvc)))
                        .collect();
                    let seqs: Vec<Vec<u8>> = (0..b)
                        .map(|s| (0..8).map(|t| ((s * 91 + t * 37) % 251) as u8).collect())
                        .collect();
                    for pos in 0..8 {
                        let rows: Vec<StepRow> = (0..b)
                            .map(|s| write_row(seqs_id[s], seqs[s][pos], pos, true))
                            .collect();
                        prep(&mut pool, &rows);
                        let batched = decode_step_batched_kv(
                            &cfg, &w, &NoOverride, Some(&kvc), &mut pool, &rows, workers,
                        )
                        .unwrap();
                        for s in 0..b {
                            let seq = decode_step_kv(
                                &cfg,
                                &w,
                                &NoOverride,
                                Some(&kvc),
                                &mut caches[s],
                                seqs[s][pos],
                                pos,
                            )
                            .unwrap();
                            assert_bits_eq(
                                &batched[s * cfg.vocab..(s + 1) * cfg.vocab],
                                &seq,
                                &format!("{name} ps={page_size} w={workers} seq {s} pos {pos}"),
                            );
                        }
                    }
                }
            }
        }
    }

    /// kv-ratio 1.0 (identity compression) on a `with_kvc` pool is
    /// bit-identical to today's uncompressed path on a plain pool — the
    /// identity layers take literally the legacy code path.
    #[test]
    fn kv_compress_identity_batched_step_bit_identical() {
        for name in ["llama-t", "opt-t"] {
            let (cfg, w) = tiny(name);
            let kvc = KvCompression::identity(cfg.n_layers);
            let mut plain_pool = KvPool::new(&cfg, 6, 2);
            let mut id_pool = KvPool::with_kvc(&cfg, 6, 2, Some(&kvc));
            assert_eq!(plain_pool.page_bytes(), id_pool.page_bytes());
            let sp = plain_pool.new_seq();
            let si = id_pool.new_seq();
            for pos in 0..6 {
                let token = ((pos * 73 + 19) % 251) as u8;
                let rp = [write_row(sp, token, pos, true)];
                let ri = [write_row(si, token, pos, true)];
                prep(&mut plain_pool, &rp);
                prep(&mut id_pool, &ri);
                let plain =
                    decode_step_batched(&cfg, &w, &NoOverride, &mut plain_pool, &rp, 2).unwrap();
                let ident = decode_step_batched_kv(
                    &cfg, &w, &NoOverride, Some(&kvc), &mut id_pool, &ri, 2,
                )
                .unwrap();
                assert_bits_eq(&ident, &plain, &format!("{name} identity kvc pos {pos}"));
            }
        }
    }

    /// A whole prompt as ONE multi-row chunk under compression matches the
    /// position-by-position sequential compressed oracle — including the
    /// sliding-window family, where the span base moves off zero.
    #[test]
    fn kv_compress_chunked_prefill_matches_oracle() {
        for name in ["llama-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            let kvc = compress_kv_plain(&cfg, &w, 0.5, &SvdPolicy::exact()).unwrap();
            let prompt: Vec<u8> = (0..7).map(|t| (t * 41 + 3) as u8).collect();
            let mut reference = Vec::new();
            let mut cache = KvCache::with_kvc(&cfg, cfg.max_seq, Some(&kvc));
            for (pos, &t) in prompt.iter().enumerate() {
                reference =
                    decode_step_kv(&cfg, &w, &NoOverride, Some(&kvc), &mut cache, t, pos)
                        .unwrap();
            }
            for &page_size in &[1usize, 2, 16] {
                let mut pool = KvPool::with_kvc(
                    &cfg,
                    prompt.len().div_ceil(page_size),
                    page_size,
                    Some(&kvc),
                );
                let s = pool.new_seq();
                let rows: Vec<StepRow> = prompt
                    .iter()
                    .enumerate()
                    .map(|(pos, &t)| write_row(s, t, pos, pos + 1 == prompt.len()))
                    .collect();
                prep(&mut pool, &rows);
                let logits = decode_step_batched_kv(
                    &cfg, &w, &NoOverride, Some(&kvc), &mut pool, &rows, 2,
                )
                .unwrap();
                let v = cfg.vocab;
                assert_bits_eq(
                    &logits[(prompt.len() - 1) * v..],
                    &reference,
                    &format!("{name} ps={page_size} compressed one-chunk prefill"),
                );
            }
        }
    }

    /// Int8-quantized KV factors (PR 7 composition): the batched step and
    /// the sequential oracle share the quantized projection path through
    /// `gemm_i8_nn`, so per-row bits still match at every worker count —
    /// no silent wrong numbers.
    #[test]
    fn kv_compress_int8_factors_match_sequential_oracle() {
        let (cfg, w) = tiny("llama-t");
        let mut kvc = compress_kv_plain(&cfg, &w, 0.5, &SvdPolicy::exact()).unwrap();
        kvc.quantize(crate::linalg::quant::DEFAULT_GROUP);
        assert!(kvc.is_quantized());
        for &workers in &[1usize, 4] {
            let mut pool = KvPool::with_kvc(&cfg, 8, 2, Some(&kvc));
            let s = pool.new_seq();
            let mut cache = KvCache::with_kvc(&cfg, cfg.max_seq, Some(&kvc));
            for pos in 0..8 {
                let token = ((pos * 57 + 5) % 251) as u8;
                let rows = [write_row(s, token, pos, true)];
                prep(&mut pool, &rows);
                let batched = decode_step_batched_kv(
                    &cfg, &w, &NoOverride, Some(&kvc), &mut pool, &rows, workers,
                )
                .unwrap();
                let seq =
                    decode_step_kv(&cfg, &w, &NoOverride, Some(&kvc), &mut cache, token, pos)
                        .unwrap();
                assert_bits_eq(&batched, &seq, &format!("int8 kvc w={workers} pos {pos}"));
            }
        }
    }
}
