//! The batched decode step: B active sequences, one token row each, every
//! projection as ONE GEMM over the stacked rows.
//!
//! This is where the kernel layer finally earns decode throughput: the
//! sequential [`decode_step`](crate::model::generate::decode_step) runs
//! each of the ~7 projections per layer as a 1-row GEMM (a matvec), so a
//! batch of B sequences costs `B × layers × 7` matvecs.  Stacking the B
//! rows turns that into `layers × 7` GEMMs of height B — same flops, far
//! better operand reuse through [`crate::linalg::gemm`]'s packed panels.
//!
//! **Bit-identity contract.**  Per request, the batched step reproduces the
//! sequential step bit-for-bit at every batch size and worker count:
//!
//! * the GEMM's per-element accumulation order is ascending-k within K
//!   blocks regardless of the row count, row position, or worker count, so
//!   row r of `[B, d] @ W` equals the 1-row product of that row alone;
//! * everything that is *not* a GEMM (norms, RoPE, attention over the
//!   sequence's own KV slot, activation nonlinearities) runs per row
//!   through the same crate-private helpers the sequential path calls
//!   (`rmsnorm_row`, `rope_row`, `attend_row`, …);
//! * compressed overrides ([`LinearOverride`]) route through the same
//!   factor GEMMs, which batch the same way.
//!
//! The parity tests at the bottom pin logits bit-equality against
//! `decode_step`, including staggered positions (mid-stream joins).

use super::kv_pool::KvPool;
use crate::linalg::gemm;
use crate::model::config::{Family, ModelConfig};
use crate::model::forward::{matmul_f32, LinearOverride};
use crate::model::generate::{attend_row, layernorm_row, rmsnorm_row, rope_row};
use crate::model::weights::Weights;
use anyhow::Result;

/// Normalize every d-wide row of `h` in place — RMSNorm when `bias` is
/// `None`, OPT LayerNorm otherwise.  The caller fetches the norm weights
/// once per layer; the per-row math is the sequential path's helpers.
fn norm_rows(h: &mut [f32], d: usize, w: &[f32], bias: Option<&[f32]>) {
    for hr in h.chunks_mut(d) {
        match bias {
            Some(bias) => layernorm_row(hr, w, bias),
            None => rmsnorm_row(hr, w),
        }
    }
}

/// One active sequence's contribution to a decode step.
#[derive(Clone, Copy, Debug)]
pub struct StepRow {
    /// KV-pool slot owned by this sequence (distinct per row).
    pub slot: usize,
    /// Token fed this step (prompt token while prefilling, last sampled
    /// token while decoding).
    pub token: u8,
    /// Position of `token` in the sequence (0-based).
    pub pos: usize,
    /// Will the caller read this row's logits?  `false` while prefilling
    /// (all but the last prompt token): the row still updates its KV slot,
    /// but the lm_head GEMM — the dominant per-step cost at real vocab
    /// sizes — skips it and its logits row is returned zeroed.
    pub needs_logits: bool,
}

/// One decode step over `rows.len()` sequences: feed each row's token at
/// its own position, append K/V to each row's slot, and return the stacked
/// logits `[rows.len(), vocab]` (row order = `rows` order; rows with
/// `needs_logits == false` are zeroed — their lm_head product is skipped).
///
/// `workers` is the GEMM thread share for the stacked products
/// (0 = all cores); results are bit-identical for every value.  Rows must
/// reference **distinct** slots, and each slot's positions must advance
/// contiguously (`pos == pool.len(slot)`), which the batcher guarantees
/// (both are debug-asserted).
///
/// LOCKSTEP WARNING: this is the batched twin of the sequential
/// [`decode_step`](crate::model::generate::decode_step) — the transformer
/// math here must mirror that function operation-for-operation (the
/// layering rule keeps it out of `model/`, which cannot import the L3 KV
/// pool).  Any model change must be made in BOTH, and the ci.sh parity
/// smokes (`cargo test -q serve`, `perf_serve -- parity`) pin the
/// bit-identity.
pub fn decode_step_batched(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    pool: &mut KvPool,
    rows: &[StepRow],
    workers: usize,
) -> Result<Vec<f32>> {
    let b = rows.len();
    if b == 0 {
        return Ok(Vec::new());
    }
    #[cfg(debug_assertions)]
    for (r, row) in rows.iter().enumerate() {
        debug_assert_eq!(
            row.pos,
            pool.len(row.slot),
            "step row {r}: pos must equal the slot's committed length \
             (positions advance contiguously per slot)"
        );
        for prev in &rows[..r] {
            debug_assert_ne!(
                prev.slot, row.slot,
                "step rows must reference distinct KV slots"
            );
        }
    }
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let _gemm_threads = gemm::scoped_workers(if workers == 0 {
        crate::util::threads::default_workers()
    } else {
        workers
    });

    let tok_emb = weights.get("tok_emb")?;
    let mut x = vec![0.0f32; b * d];
    for (r, row) in rows.iter().enumerate() {
        x[r * d..(r + 1) * d].copy_from_slice(tok_emb.row(row.token as usize));
    }
    if cfg.family == Family::Opt {
        let pos_emb = weights.get("pos_emb")?;
        for (r, row) in rows.iter().enumerate() {
            for j in 0..d {
                x[r * d + j] += pos_emb.at2(row.pos.min(cfg.max_seq - 1), j);
            }
        }
    }
    // One GEMM per weight over the stacked rows (or the override's factor
    // GEMMs — CompressedLayer::apply batches identically).
    let lin = |name: &str, h: &[f32], in_dim: usize| -> Result<Vec<f32>> {
        if let Some(y) = overrides.apply(name, h, b, in_dim) {
            return Ok(y);
        }
        Ok(matmul_f32(h, b, in_dim, weights.get(name)?))
    };
    for i in 0..cfg.n_layers {
        let mut h = x.clone();
        let nw = &weights.get(&format!("blocks.{i}.attn_norm.w"))?.data;
        let nb = match cfg.family {
            Family::Opt => Some(weights.get(&format!("blocks.{i}.attn_norm.b"))?.data.as_slice()),
            _ => None,
        };
        norm_rows(&mut h, d, nw, nb);
        let mut q = lin(&format!("blocks.{i}.attn.wq"), &h, d)?;
        let mut k = lin(&format!("blocks.{i}.attn.wk"), &h, d)?;
        let v = lin(&format!("blocks.{i}.attn.wv"), &h, d)?;
        for (r, row) in rows.iter().enumerate() {
            if cfg.family.uses_rope() {
                rope_row(&mut q[r * d..(r + 1) * d], heads, hd, row.pos);
                rope_row(&mut k[r * d..(r + 1) * d], heads, hd, row.pos);
            }
            pool.push_row(row.slot, i, row.pos, &k[r * d..(r + 1) * d], &v[r * d..(r + 1) * d]);
        }
        // Attention stays per row: each sequence attends over its own slot
        // (identical float-op order to the sequential path via attend_row).
        let mut att = vec![0.0f32; b * d];
        for (r, row) in rows.iter().enumerate() {
            let t_now = row.pos + 1;
            let lo = if cfg.window > 0 { t_now.saturating_sub(cfg.window) } else { 0 };
            attend_row(
                &q[r * d..(r + 1) * d],
                pool.k_hist(row.slot, i, t_now),
                pool.v_hist(row.slot, i, t_now),
                heads,
                hd,
                scale,
                lo,
                t_now,
                &mut att[r * d..(r + 1) * d],
            );
        }
        let o = lin(&format!("blocks.{i}.attn.wo"), &att, d)?;
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let mut h = x.clone();
        let nw = &weights.get(&format!("blocks.{i}.mlp_norm.w"))?.data;
        let nb = match cfg.family {
            Family::Opt => Some(weights.get(&format!("blocks.{i}.mlp_norm.b"))?.data.as_slice()),
            _ => None,
        };
        norm_rows(&mut h, d, nw, nb);
        let m = if cfg.family == Family::Opt {
            let mut u = lin(&format!("blocks.{i}.mlp.fc1"), &h, d)?;
            for uv in u.iter_mut() {
                *uv = uv.max(0.0);
            }
            lin(&format!("blocks.{i}.mlp.fc2"), &u, cfg.d_ff)?
        } else {
            let mut g = lin(&format!("blocks.{i}.mlp.w_gate"), &h, d)?;
            let u = lin(&format!("blocks.{i}.mlp.w_up"), &h, d)?;
            for (gv, uv) in g.iter_mut().zip(&u) {
                let sg = *gv / (1.0 + (-*gv).exp());
                *gv = sg * uv;
            }
            lin(&format!("blocks.{i}.mlp.w_down"), &g, cfg.d_ff)?
        };
        for (xv, mv) in x.iter_mut().zip(&m) {
            *xv += mv;
        }
    }
    let nw = &weights.get("final_norm.w")?.data;
    let nb = match cfg.family {
        Family::Opt => Some(weights.get("final_norm.b")?.data.as_slice()),
        _ => None,
    };
    norm_rows(&mut x, d, nw, nb);
    for row in rows {
        pool.set_len(row.slot, row.pos + 1);
    }
    // lm_head only over the rows whose logits the caller reads — prefill
    // rows' logits are discarded, and at a real vocab the lm_head GEMM
    // dominates the step.  The GEMM is row-independent, so the computed
    // rows are bit-identical to the all-rows product; skipped rows come
    // back zeroed.
    let lm_head = weights.get("lm_head")?;
    if rows.iter().all(|row| row.needs_logits) {
        return Ok(matmul_f32(&x, b, d, lm_head));
    }
    let need: Vec<usize> = (0..b).filter(|&r| rows[r].needs_logits).collect();
    let vocab = cfg.vocab;
    let mut logits = vec![0.0f32; b * vocab];
    if !need.is_empty() {
        let mut xs = vec![0.0f32; need.len() * d];
        for (j, &r) in need.iter().enumerate() {
            xs[j * d..(j + 1) * d].copy_from_slice(&x[r * d..(r + 1) * d]);
        }
        let sub = matmul_f32(&xs, need.len(), d, lm_head);
        for (j, &r) in need.iter().enumerate() {
            logits[r * vocab..(r + 1) * vocab].copy_from_slice(&sub[j * vocab..(j + 1) * vocab]);
        }
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::NoOverride;
    use crate::model::generate::{decode_step, KvCache};

    fn tiny(name: &str) -> (ModelConfig, Weights) {
        crate::serve::test_util::tiny(name, 31)
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
        }
    }

    /// Lockstep batched decode vs B independent sequential decoders must be
    /// bit-identical per row, for every family and worker count.
    #[test]
    fn serve_batched_step_bit_identical_lockstep() {
        for name in ["llama-t", "opt-t", "mistral-t"] {
            let (cfg, w) = tiny(name);
            for &workers in &[1usize, 4] {
                let b = 3usize;
                let mut pool = KvPool::new(&cfg, b, 10);
                let slots: Vec<usize> = (0..b).map(|_| pool.acquire().unwrap()).collect();
                let mut caches: Vec<KvCache> = (0..b).map(|_| KvCache::new(&cfg)).collect();
                let seqs: Vec<Vec<u8>> = (0..b)
                    .map(|s| (0..8).map(|t| ((s * 91 + t * 37) % 251) as u8).collect())
                    .collect();
                for pos in 0..8 {
                    let rows: Vec<StepRow> = (0..b)
                        .map(|s| StepRow {
                            slot: slots[s],
                            token: seqs[s][pos],
                            pos,
                            needs_logits: true,
                        })
                        .collect();
                    let batched =
                        decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, workers)
                            .unwrap();
                    for s in 0..b {
                        let seq = decode_step(
                            &cfg, &w, &NoOverride, &mut caches[s], seqs[s][pos], pos,
                        )
                        .unwrap();
                        assert_bits_eq(
                            &batched[s * cfg.vocab..(s + 1) * cfg.vocab],
                            &seq,
                            &format!("{name} w={workers} seq {s} pos {pos}"),
                        );
                    }
                }
            }
        }
    }

    /// A sequence joining mid-stream (staggered positions within one batch)
    /// must match a fresh sequential run bit-for-bit.
    #[test]
    fn serve_batched_step_bit_identical_staggered_join() {
        let (cfg, w) = tiny("llama-t");
        let mut pool = KvPool::new(&cfg, 2, 12);
        let sa = pool.acquire().unwrap();
        let seq_a: Vec<u8> = (0..9).map(|t| (t * 53 % 256) as u8).collect();
        let seq_b: Vec<u8> = (0..6).map(|t| (t * 29 + 7) as u8).collect();
        let mut cache_a = KvCache::new(&cfg);
        let mut cache_b = KvCache::new(&cfg);
        // A runs alone for 3 steps.
        for pos in 0..3 {
            let rows =
                [StepRow { slot: sa, token: seq_a[pos], pos, needs_logits: true }];
            let batched =
                decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
            let seq = decode_step(&cfg, &w, &NoOverride, &mut cache_a, seq_a[pos], pos).unwrap();
            assert_bits_eq(&batched, &seq, &format!("solo A pos {pos}"));
        }
        // B joins at step 3: batch rows now at staggered positions.
        let sb = pool.acquire().unwrap();
        for t in 0..6 {
            let pos_a = 3 + t;
            let rows = [
                StepRow { slot: sa, token: seq_a[pos_a], pos: pos_a, needs_logits: true },
                StepRow { slot: sb, token: seq_b[t], pos: t, needs_logits: true },
            ];
            let batched =
                decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 4).unwrap();
            let ref_a =
                decode_step(&cfg, &w, &NoOverride, &mut cache_a, seq_a[pos_a], pos_a).unwrap();
            let ref_b = decode_step(&cfg, &w, &NoOverride, &mut cache_b, seq_b[t], t).unwrap();
            let v = cfg.vocab;
            assert_bits_eq(&batched[..v], &ref_a, &format!("joined A step {t}"));
            assert_bits_eq(&batched[v..2 * v], &ref_b, &format!("joined B step {t}"));
        }
        assert_eq!(pool.len(sa), 9);
        assert_eq!(pool.len(sb), 6);
    }

    #[test]
    fn serve_batched_step_skips_prefill_logits() {
        let (cfg, w) = tiny("llama-t");
        let mut pool = KvPool::new(&cfg, 2, 4);
        let s0 = pool.acquire().unwrap();
        let s1 = pool.acquire().unwrap();
        let rows = [
            StepRow { slot: s0, token: 9, pos: 0, needs_logits: true },
            StepRow { slot: s1, token: 17, pos: 0, needs_logits: false },
        ];
        let both = decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &rows, 1).unwrap();
        let v = cfg.vocab;
        // The prefill row's logits come back zeroed, the other row stays
        // bit-identical to a sequential decode of it alone.
        assert!(both[v..2 * v].iter().all(|&x| x == 0.0));
        let mut cache = KvCache::new(&cfg);
        let seq = decode_step(&cfg, &w, &NoOverride, &mut cache, 9, 0).unwrap();
        assert_bits_eq(&both[..v], &seq, "needs_logits row");
        // The skipped row's KV still advanced.
        assert_eq!(pool.len(s1), 1);
    }

    #[test]
    fn serve_batched_step_empty_batch_is_noop() {
        let (cfg, w) = tiny("llama-t");
        let mut pool = KvPool::new(&cfg, 1, 4);
        let out = decode_step_batched(&cfg, &w, &NoOverride, &mut pool, &[], 1).unwrap();
        assert!(out.is_empty());
    }
}
