"""Build-time training of the tiny model zoo.

The paper compresses pretrained LLMs; offline we must pretrain our own.  Each
model is trained on a *mixture* of all eight domains (English-heavy, with
CN/JP minorities — like real LLM pretraining mixes) so that it is competent
everywhere, then CALIBRATED later on the wiki train split only.  That gap
between the pretraining mixture and the calibration distribution is exactly
what Tables 1/2 probe.

Runs once at ``make artifacts``.  Adam + cosine schedule, pure-jnp forward
(the Pallas kernels are for the lowered artifacts; training wants XLA's
fused dense paths).
"""

from __future__ import annotations

import math
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import corpora, model
from .weights_io import save_weights

SEQ = 128

# Pretraining mixture: English domains in the lead, CN/JP minorities.
MIX_WEIGHTS = {
    "wiki": 0.14, "ptb": 0.11, "c4": 0.11, "snips": 0.10,
    "alpaca": 0.10, "mctest": 0.10, "cmrc_cn": 0.17, "alpaca_jp": 0.17,
}

TRAIN_STEPS = {
    "llama-t": 400, "llama-s": 300, "llama-m": 220,
    "opt-t": 400, "mistral-t": 400, "vicuna-t": 150,
}
BATCH = {"llama-t": 16, "llama-s": 12, "llama-m": 8,
         "opt-t": 16, "mistral-t": 16, "vicuna-t": 16}


class MixtureSampler:
    """Samples [batch, SEQ] windows from the domain mixture."""

    def __init__(self, corpora_dir: Path, rng: np.random.Generator,
                 weights: dict[str, float] | None = None):
        self.rng = rng
        self.weights = weights or MIX_WEIGHTS
        self.streams = {}
        for name in self.weights:
            toks = corpora.read_tokens(corpora_dir / f"{name}.train.tok")
            self.streams[name] = np.array(toks, dtype=np.int32)
        self.names = list(self.weights)
        self.probs = np.array([self.weights[n] for n in self.names])
        self.probs = self.probs / self.probs.sum()

    def batch(self, batch_size: int) -> np.ndarray:
        out = np.zeros((batch_size, SEQ), dtype=np.int32)
        picks = self.rng.choice(len(self.names), size=batch_size, p=self.probs)
        for b, pi in enumerate(picks):
            stream = self.streams[self.names[pi]]
            start = self.rng.integers(0, len(stream) - SEQ)
            out[b] = stream[start:start + SEQ]
        return out


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.float32)}


@partial(jax.jit, static_argnames=("cfg", "lr_max", "total_steps"))
def train_step(cfg, params, opt, tokens, lr_max, total_steps):
    def mean_loss(p):
        sum_nll, count = model.loss_fn(cfg, p, tokens)
        return sum_nll / count

    loss, grads = jax.value_and_grad(mean_loss)(params)
    t = opt["t"] + 1.0
    # Cosine schedule with 20-step warmup.
    warm = jnp.minimum(t / 20.0, 1.0)
    progress = jnp.clip(t / total_steps, 0.0, 1.0)
    lr = lr_max * warm * 0.5 * (1.0 + jnp.cos(math.pi * progress))
    b1, b2, eps = 0.9, 0.98, 1e-8
    new_m, new_v, new_p = {}, {}, {}
    for k, g in grads.items():
        m = b1 * opt["m"][k] + (1 - b1) * g
        v = b2 * opt["v"][k] + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        new_m[k] = m
        new_v[k] = v
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, {"m": new_m, "v": new_v, "t": t}, loss


def train_model(name: str, corpora_dir: Path, out_dir: Path,
                init_from: dict | None = None,
                mixture: dict[str, float] | None = None,
                steps: int | None = None, log_every: int = 50) -> dict:
    cfg = model.CONFIGS[name]
    steps = steps if steps is not None else TRAIN_STEPS[name]
    batch = BATCH[name]
    rng = np.random.default_rng(hash(name) % (2 ** 31))
    sampler = MixtureSampler(corpora_dir, rng, mixture)
    if init_from is not None:
        params = {k: jnp.asarray(v) for k, v in init_from.items()}
    else:
        params = model.init_params(cfg, jax.random.PRNGKey(hash(name) % (2 ** 31)))
    opt = adam_init(params)
    t0 = time.time()
    losses = []
    for step in range(steps):
        tokens = jnp.asarray(sampler.batch(batch))
        params, opt, loss = train_step(cfg, params, opt, tokens,
                                       lr_max=3e-3, total_steps=steps)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"  [{name}] step {step:4d}/{steps} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    out_path = out_dir / f"{name}.nsvdw"
    save_weights(out_path, {k: np.asarray(v) for k, v in params.items()})
    print(f"  [{name}] saved {out_path} (final loss {losses[-1]:.4f})", flush=True)
    return {k: np.asarray(v) for k, v in params.items()}


def train_zoo(corpora_dir: Path, out_dir: Path) -> None:
    """Train the full model zoo (vicuna-t fine-tunes from llama-t)."""
    out_dir.mkdir(parents=True, exist_ok=True)
    llama_t = train_model("llama-t", corpora_dir, out_dir)
    # Vicuna := llama-t + instruction-corpus fine-tune.
    train_model("vicuna-t", corpora_dir, out_dir, init_from=llama_t,
                mixture={"alpaca": 0.85, "wiki": 0.15})
    for name in ("llama-s", "llama-m", "opt-t", "mistral-t"):
        train_model(name, corpora_dir, out_dir)
