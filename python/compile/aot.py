"""AOT driver: corpora → trained weights → HLO-text artifacts + manifest.

Runs ONCE at ``make artifacts``; the Rust binary is self-contained afterwards.

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the image's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per architecture (vicuna-t shares llama-t's):

* ``{arch}_dense_b{B}``   — tokens + weights → (sum_nll, token_count)
* ``{arch}_gram_b{B}``    — tokens + weights → (sum_nll, count, gram per tap)
* ``{arch}_lowrank_b{B}`` — tokens + weights + padded nested factors →
                            (sum_nll, token_count)

Every lowered function takes a FLAT argument list (tokens first, then arrays
in the manifest's recorded order) so the Rust side can marshal positionally.
"""

from __future__ import annotations

import argparse
import hashlib
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import corpora, model, train
from .weights_io import load_weights

EVAL_BATCH = 8
SERVE_BATCH = 1
SEQ = 128

MODELS = ["llama-t", "vicuna-t", "llama-s", "llama-m", "opt-t", "mistral-t"]
ARCHS = ["llama-t", "llama-s", "llama-m", "opt-t", "mistral-t"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sources_digest() -> str:
    """Hash of the compile-path sources; artifact staleness check."""
    here = Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(here.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def factor_order(cfg) -> list[str]:
    """Canonical ordering of compressible weights for the factor arg list."""
    return sorted(model.linear_shapes(cfg).keys())


def lower_dense(cfg, params, batch: int) -> str:
    names = sorted(params.keys())

    def fn(tokens, *arrays):
        p = dict(zip(names, arrays))
        return model.loss_fn(cfg, p, tokens)

    tok_spec = jax.ShapeDtypeStruct((batch, SEQ), jnp.int32)
    arg_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    return to_hlo_text(jax.jit(fn).lower(tok_spec, *arg_specs))


def lower_gram(cfg, params, batch: int) -> tuple[str, list[str]]:
    names = sorted(params.keys())
    taps = model.tap_names(cfg)

    def fn(tokens, *arrays):
        p = dict(zip(names, arrays))
        sum_nll, count, grams, abssums = model.loss_and_grams_fn(cfg, p, tokens)
        # Output order: scalars, then all Grams in tap order, then abs-sums.
        return (sum_nll, count, *[grams[t] for t in taps],
                *[abssums[t] for t in taps])

    tok_spec = jax.ShapeDtypeStruct((batch, SEQ), jnp.int32)
    arg_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    return to_hlo_text(jax.jit(fn).lower(tok_spec, *arg_specs)), taps


def lower_lowrank(cfg, params, batch: int) -> tuple[str, list[str], dict, list[str]]:
    """Lower the factored forward.  The dense copies of the compressed
    weights are NOT passed (jax prunes unused parameters from the lowered
    module, which would break positional marshaling); only the residual
    dense params (embeddings, norms, lm_head) are arguments."""
    worder = factor_order(cfg)
    names = [n for n in sorted(params.keys()) if n not in set(worder)]
    shapes = model.linear_shapes(cfg)
    ranks = {w: model.max_ranks(*shapes[w]) for w in worder}

    def fn(tokens, *arrays):
        p = dict(zip(names, arrays[: len(names)]))
        fac_arrays = arrays[len(names):]
        factors = {}
        for wi, w in enumerate(worder):
            factors[w] = tuple(fac_arrays[4 * wi: 4 * wi + 4])
        return model.lowrank_loss_fn(cfg, p, factors, tokens)

    tok_spec = jax.ShapeDtypeStruct((batch, SEQ), jnp.int32)
    arg_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    for w in worder:
        n_in, n_out = shapes[w]
        k1m, k2m = ranks[w]
        arg_specs += [
            jax.ShapeDtypeStruct((n_in, k1m), jnp.float32),
            jax.ShapeDtypeStruct((k1m, n_out), jnp.float32),
            jax.ShapeDtypeStruct((n_in, k2m), jnp.float32),
            jax.ShapeDtypeStruct((k2m, n_out), jnp.float32),
        ]
    return to_hlo_text(jax.jit(fn).lower(tok_spec, *arg_specs)), worder, ranks, names


def lower_serve(cfg, params, batch: int) -> tuple[str, list[str], dict, list[str]]:
    """Serving executable: factored forward with per-row (nll, count) outputs
    so the dynamic batcher can score independent requests in one call."""
    worder = factor_order(cfg)
    names = [n for n in sorted(params.keys()) if n not in set(worder)]
    shapes = model.linear_shapes(cfg)
    ranks = {w: model.max_ranks(*shapes[w]) for w in worder}

    def fn(tokens, *arrays):
        p = dict(zip(names, arrays[: len(names)]))
        fac_arrays = arrays[len(names):]
        factors = {w: tuple(fac_arrays[4 * wi: 4 * wi + 4])
                   for wi, w in enumerate(worder)}
        return model.lowrank_rowloss_fn(cfg, p, factors, tokens)

    tok_spec = jax.ShapeDtypeStruct((batch, SEQ), jnp.int32)
    arg_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    for w in worder:
        n_in, n_out = shapes[w]
        k1m, k2m = ranks[w]
        arg_specs += [
            jax.ShapeDtypeStruct((n_in, k1m), jnp.float32),
            jax.ShapeDtypeStruct((k1m, n_out), jnp.float32),
            jax.ShapeDtypeStruct((n_in, k2m), jnp.float32),
            jax.ShapeDtypeStruct((k2m, n_out), jnp.float32),
        ]
    return to_hlo_text(jax.jit(fn).lower(tok_spec, *arg_specs)), worder, ranks, names


def build(out_dir: Path, force: bool = False) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    digest = _sources_digest()
    if manifest_path.exists() and not force:
        try:
            old = json.loads(manifest_path.read_text())
            if old.get("digest") == digest:
                print("artifacts up to date (digest match); skipping")
                return
        except (json.JSONDecodeError, OSError):
            pass

    print("== corpora ==", flush=True)
    corp_manifest = corpora.build_all(out_dir / "corpora")

    print("== training zoo ==", flush=True)
    weights_dir = out_dir / "models"
    missing = [m for m in MODELS if not (weights_dir / f"{m}.nsvdw").exists()]
    if missing or force:
        train.train_zoo(out_dir / "corpora", weights_dir)
    else:
        print("  all weights present; skipping training")

    print("== lowering ==", flush=True)
    artifacts: dict[str, dict] = {}
    for arch in ARCHS:
        cfg = model.CONFIGS[arch]
        params = load_weights(weights_dir / f"{arch}.nsvdw")
        names = sorted(params.keys())
        batches = [EVAL_BATCH] + ([SERVE_BATCH] if arch == "llama-t" else [])
        for b in batches:
            key = f"{arch}_dense_b{b}"
            path = out_dir / f"{key}.hlo.txt"
            path.write_text(lower_dense(cfg, params, b))
            artifacts[key] = {
                "file": path.name, "kind": "dense", "arch": arch,
                "batch": b, "seq": SEQ, "params": names,
                "outputs": ["sum_nll", "count"],
            }
            print(f"  wrote {path.name}", flush=True)

            key = f"{arch}_lowrank_b{b}"
            path = out_dir / f"{key}.hlo.txt"
            hlo, worder, ranks, lr_names = lower_lowrank(cfg, params, b)
            path.write_text(hlo)
            artifacts[key] = {
                "file": path.name, "kind": "lowrank", "arch": arch,
                "batch": b, "seq": SEQ, "params": lr_names,
                "factor_order": worder,
                "factor_ranks": {w: list(ranks[w]) for w in worder},
                "outputs": ["sum_nll", "count"],
            }
            print(f"  wrote {path.name}", flush=True)

        if arch == "llama-t":
            key = f"{arch}_serve_b{EVAL_BATCH}"
            path = out_dir / f"{key}.hlo.txt"
            hlo, worder, ranks, sv_names = lower_serve(cfg, params, EVAL_BATCH)
            path.write_text(hlo)
            artifacts[key] = {
                "file": path.name, "kind": "serve", "arch": arch,
                "batch": EVAL_BATCH, "seq": SEQ, "params": sv_names,
                "factor_order": worder,
                "factor_ranks": {w: list(ranks[w]) for w in worder},
                "outputs": ["row_nll", "row_count"],
            }
            print(f"  wrote {path.name}", flush=True)

        key = f"{arch}_gram_b{EVAL_BATCH}"
        path = out_dir / f"{key}.hlo.txt"
        hlo, taps = lower_gram(cfg, params, EVAL_BATCH)
        path.write_text(hlo)
        artifacts[key] = {
            "file": path.name, "kind": "gram", "arch": arch,
            "batch": EVAL_BATCH, "seq": SEQ, "params": names,
            "outputs": ["sum_nll", "count"], "taps": taps,
        }
        print(f"  wrote {path.name}", flush=True)

    models_meta = {}
    for name in MODELS:
        cfg = model.CONFIGS[name]
        models_meta[name] = {
            "family": cfg.family, "arch": model.ARCH_OF[name],
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq, "window": cfg.window, "vocab": cfg.vocab,
            "weights": f"models/{name}.nsvdw",
            "linear_shapes": {k: list(v) for k, v in model.linear_shapes(cfg).items()},
        }

    manifest = {
        "digest": digest,
        "seq": SEQ,
        "eval_batch": EVAL_BATCH,
        "corpora": {k: {"train": Path(v["train"]).name,
                        "test": Path(v["test"]).name,
                        "kind": v["kind"]}
                    for k, v in corp_manifest.items()},
        "models": models_meta,
        "artifacts": artifacts,
    }
    manifest_path.write_text(json.dumps(manifest, indent=2))
    print(f"wrote {manifest_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    build(Path(args.out_dir), force=args.force)


if __name__ == "__main__":
    main()
