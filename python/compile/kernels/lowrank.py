"""Fused nested low-rank apply — the paper's request-path hot-spot (Eq. 6).

``y = (x P1) Q1 + (x P2) Q2`` where (P1, Q1) are the activation-aware stage-1
factors and (P2, Q2) the residual stage-2 factors of NSVD.  Fusing both rank
branches over a shared x tile means x is read from HBM **once** per tile —
that is the TPU re-think of the paper's GPU formulation, where the two
branches would be separate GEMM launches.

The grid tiles rows of x; every factor is small enough to stay VMEM-resident
across the whole grid (k1max ≤ 108, k2max ≤ 27 at our model sizes: factors
total < 0.5 MiB).  Zero-padded rank columns multiply to zero, which is what
makes the single fixed-shape executable serve every compression ratio.

Complexity matches the paper's ``O(2n(p+m)(k1+k2))`` flop count — the fusion
changes memory traffic, not arithmetic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nested_kernel(x_ref, p1_ref, q1_ref, p2_ref, q2_ref, o_ref):
    x = x_ref[...]
    # Stage 1 (activation-aware factors) and stage 2 (residual factors)
    # share the x tile; both contractions run back-to-back on the MXU.
    h1 = jnp.dot(x, p1_ref[...], preferred_element_type=jnp.float32)
    y1 = jnp.dot(h1, q1_ref[...], preferred_element_type=jnp.float32)
    h2 = jnp.dot(x, p2_ref[...], preferred_element_type=jnp.float32)
    y2 = jnp.dot(h2, q2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y1 + y2


def nested_apply(x, p1, q1, p2, q2, bm: int = 128) -> jax.Array:
    """x [M, n] with factors P1 [n, k1], Q1 [k1, m], P2 [n, k2], Q2 [k2, m]
    → y [M, m]."""
    mrows, n = x.shape
    n2, k1 = p1.shape
    k1b, mout = q1.shape
    assert n == n2 and k1 == k1b, f"stage-1 factor shapes {p1.shape} {q1.shape}"
    assert p2.shape[0] == n and q2.shape[1] == mout, "stage-2 factor shapes"
    bm = min(bm, mrows)
    grid = (pl.cdiv(mrows, bm),)
    return pl.pallas_call(
        _nested_kernel,
        out_shape=jax.ShapeDtypeStruct((mrows, mout), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec(p1.shape, lambda i: (0, 0)),
            pl.BlockSpec(q1.shape, lambda i: (0, 0)),
            pl.BlockSpec(p2.shape, lambda i: (0, 0)),
            pl.BlockSpec(q2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, mout), lambda i: (i, 0)),
        interpret=True,
    )(
        x.astype(jnp.float32),
        p1.astype(jnp.float32),
        q1.astype(jnp.float32),
        p2.astype(jnp.float32),
        q2.astype(jnp.float32),
    )
