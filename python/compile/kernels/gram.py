"""Streaming Gram accumulation Pallas kernel: ``XᵀX`` over row tiles.

Calibration needs the activation Gram of every tap (paper §3: the whitening
factor S comes from the Cholesky/eigendecomposition of ``X Xᵀ``; in our row
convention that is ``XᵀX``).  The kernel streams [bm, N] activation tiles
HBM→VMEM and accumulates the [N, N] Gram in the output block, which stays
resident in VMEM across the grid (all grid steps map to output block (0, 0)).

VMEM footprint: tile 128×N + Gram N×N; at N = 512 (largest tap) that is
128·512·4 + 512·512·4 ≈ 1.3 MiB — well under budget.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, o_ref, a_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    x = x_ref[...]
    o_ref[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)
    # Column-wise Σ|x|: the ASVD-0 baseline scales by per-dim absolute means.
    a_ref[...] += jnp.sum(jnp.abs(x), axis=0, keepdims=True)


def gram(x: jax.Array, bm: int = 128) -> tuple[jax.Array, jax.Array]:
    """``(XᵀX, Σ|x| per column)`` for x [M, N] → ([N, N], [1, N]),
    accumulated over M in tiles of bm.

    M is zero-padded up to a multiple of bm: unlike a plain matmul, the edge
    tile CONTRIBUTES to the accumulator, so out-of-bounds garbage must be
    masked — zero rows add exactly nothing to either accumulator.
    """
    m, n = x.shape
    bm = min(bm, m)
    if m % bm != 0:
        pad = bm - m % bm
        x = jnp.pad(x, ((0, pad), (0, 0)))
        m += pad
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _gram_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ),
        interpret=True,
    )(x.astype(jnp.float32))
