"""L1 — Pallas kernels (build-time; lowered with interpret=True for CPU PJRT).

Modules:

* ``matmul``  — tiled matrix multiply (MXU-shaped blocks).
* ``gram``    — streaming activation Gram accumulation ``XᵀX``.
* ``lowrank`` — the paper's request-path hot-spot: the fused nested low-rank
  apply ``y = (x P1) Q1 + (x P2) Q2`` (Eq. 6 of the paper).
* ``ref``     — pure-jnp oracles used by the pytest correctness gate.
"""
