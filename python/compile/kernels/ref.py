"""Pure-jnp oracles for the Pallas kernels.

These are the single source of truth for kernel correctness: pytest sweeps
shapes/dtypes with hypothesis and asserts the Pallas outputs match these to
tight tolerances (see python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain ``x @ w`` in f32 accumulation."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def gram_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(XᵀX, Σ|x| per column)``: [M, N] → ([N, N], [1, N])."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf, jnp.sum(jnp.abs(xf), axis=0, keepdims=True)


def nested_apply_ref(x, p1, q1, p2, q2) -> jnp.ndarray:
    """Paper Eq. 6: ``O = W̃₁(Z̃₁X) + W̃₂(Z̃₂X)`` in row convention:
    ``y = (x P1) Q1 + (x P2) Q2``."""
    xf = x.astype(jnp.float32)
    y1 = (xf @ p1.astype(jnp.float32)) @ q1.astype(jnp.float32)
    y2 = (xf @ p2.astype(jnp.float32)) @ q2.astype(jnp.float32)
    return y1 + y2
