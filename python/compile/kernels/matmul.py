"""Tiled matmul Pallas kernel.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the M and N
output dimensions in MXU-shaped blocks; the full K panel of each operand tile
is staged in VMEM and contracted on the MXU.  The K dimension of our models is
at most ``d_ff`` (≤ 512), so a [bm, K] × [K, bn] panel pair fits comfortably
in VMEM (f32: 128·512·4 + 512·128·4 = 512 KiB ≪ 16 MiB).

On CPU we must run interpret=True (the CPU PJRT plugin cannot execute Mosaic
custom-calls); correctness is gated against ``ref.matmul_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One [bm, K] × [K, bn] contraction per grid cell, f32 accumulation.
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x: jax.Array, w: jax.Array, bm: int = 128, bn: int = 128) -> jax.Array:
    """``x [M, K] @ w [K, N]`` with an (M/bm, N/bn) Pallas grid.

    M and N need not be multiples of the block size; Pallas masks the edge
    blocks.  K is kept whole per tile (small in this system).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul shape mismatch {x.shape} @ {w.shape}"
    bm = min(bm, m)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))
