"""L2 — JAX transformer families (build-time only; never on the request path).

Three families mirror the paper's model zoo (LLaMA, OPT, Mistral):

* ``llama``   — pre-norm, RMSNorm, SwiGLU MLP, rotary position embeddings.
* ``opt``     — pre-norm, LayerNorm (scale+bias), ReLU MLP, learned absolute
                position embeddings.
* ``mistral`` — llama block with sliding-window causal attention.

Each model is a pure function over a flat ``{name: array}`` parameter dict
whose names match the NSVDW weight file keys read by the Rust side
(`rust/src/model/weights.rs`).  All linear weights are stored **[in, out]**
and applied as ``y = x @ W``; the Rust compressor treats the paper's
``A = Wᵀ`` so its activation Gram is over the `in` dimension.

Three forward variants are lowered AOT (see ``aot.py``):

* ``loss_fn``           — dense forward → (sum_nll, token_count).
* ``loss_and_grams_fn`` — dense forward that additionally returns the
  per-tap activation Gram matrices ``XᵀX`` used for calibration and for the
  Table 2 / Figure 1 similarity analysis.
* ``lowrank_loss_fn``   — every compressible weight replaced by the nested
  factor quadruple ``(P1, Q1, P2, Q2)`` (zero-padded to fixed max ranks so a
  single fixed-shape PJRT executable serves every compression ratio); the
  factored apply is the L1 Pallas kernel ``kernels.lowrank.nested_apply``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import gram as gram_kernel
from .kernels import lowrank as lowrank_kernel

VOCAB = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "llama" | "opt" | "mistral"
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int = 128
    window: int = 0  # sliding window (mistral); 0 = full causal
    vocab: int = VOCAB

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


CONFIGS = {
    # LLaMA family at three scales (the paper's 7B/13B/30B axis).
    "llama-t": ModelConfig("llama-t", "llama", 128, 4, 4, 256),
    "llama-s": ModelConfig("llama-s", "llama", 160, 5, 5, 320),
    "llama-m": ModelConfig("llama-m", "llama", 192, 6, 6, 384),
    # Vicuna = LLaMA architecture + instruction fine-tune (same HLO artifact).
    "vicuna-t": ModelConfig("vicuna-t", "llama", 128, 4, 4, 256),
    "opt-t": ModelConfig("opt-t", "opt", 128, 4, 4, 384),
    "mistral-t": ModelConfig("mistral-t", "mistral", 128, 4, 4, 256, window=32),
}

# Architecture key: vicuna-t shares llama-t's lowered artifacts.
ARCH_OF = {name: ("llama-t" if name == "vicuna-t" else name) for name in CONFIGS}


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _linear_names(cfg: ModelConfig, i: int) -> list[str]:
    """Names of the compressible linear weights in block i (paper's targets)."""
    base = [f"blocks.{i}.attn.wq", f"blocks.{i}.attn.wk",
            f"blocks.{i}.attn.wv", f"blocks.{i}.attn.wo"]
    if cfg.family == "opt":
        return base + [f"blocks.{i}.mlp.fc1", f"blocks.{i}.mlp.fc2"]
    return base + [f"blocks.{i}.mlp.w_gate", f"blocks.{i}.mlp.w_up",
                   f"blocks.{i}.mlp.w_down"]


def linear_shapes(cfg: ModelConfig) -> dict[str, tuple[int, int]]:
    """[in, out] shapes for every compressible weight of the model."""
    d, f = cfg.d_model, cfg.d_ff
    shapes: dict[str, tuple[int, int]] = {}
    for i in range(cfg.n_layers):
        for name in _linear_names(cfg, i):
            leaf = name.rsplit(".", 1)[1]
            if leaf in ("wq", "wk", "wv", "wo"):
                shapes[name] = (d, d)
            elif leaf in ("w_gate", "w_up", "fc1"):
                shapes[name] = (d, f)
            elif leaf in ("w_down", "fc2"):
                shapes[name] = (f, d)
    return shapes


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    """Scaled-normal initialization; returns the flat name→array dict."""
    params: dict[str, jax.Array] = {}
    d = cfg.d_model

    def norm_init(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    key, k_emb, k_head = jax.random.split(key, 3)
    params["tok_emb"] = norm_init(k_emb, (cfg.vocab, d), 0.02)
    params["lm_head"] = norm_init(k_head, (d, cfg.vocab), 0.02)
    if cfg.family == "opt":
        key, k_pos = jax.random.split(key)
        params["pos_emb"] = norm_init(k_pos, (cfg.max_seq, d), 0.02)
    shapes = linear_shapes(cfg)
    for i in range(cfg.n_layers):
        for name in _linear_names(cfg, i):
            key, k = jax.random.split(key)
            shape = shapes[name]
            scale = 1.0 / math.sqrt(shape[0])
            # Residual-path projections get the depth-scaled init.
            if name.endswith(("wo", "w_down", "fc2")):
                scale /= math.sqrt(2.0 * cfg.n_layers)
            params[name] = norm_init(k, shape, scale)
        params[f"blocks.{i}.attn_norm.w"] = jnp.ones((d,), jnp.float32)
        params[f"blocks.{i}.mlp_norm.w"] = jnp.ones((d,), jnp.float32)
        if cfg.family == "opt":
            params[f"blocks.{i}.attn_norm.b"] = jnp.zeros((d,), jnp.float32)
            params[f"blocks.{i}.mlp_norm.b"] = jnp.zeros((d,), jnp.float32)
    params["final_norm.w"] = jnp.ones((d,), jnp.float32)
    if cfg.family == "opt":
        params["final_norm.b"] = jnp.zeros((d,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def _norm(cfg: ModelConfig, params, prefix: str, x: jax.Array) -> jax.Array:
    if cfg.family == "opt":
        return layernorm(x, params[f"{prefix}.w"], params[f"{prefix}.b"])
    return rmsnorm(x, params[f"{prefix}.w"])


def rope_tables(seq: int, head_dim: int) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables [seq, head_dim] (split-halves convention)."""
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.concatenate([jnp.cos(angles), jnp.cos(angles)], axis=-1)
    sin = jnp.concatenate([jnp.sin(angles), jnp.sin(angles)], axis=-1)
    return cos, sin


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, T, H, hd]; rotate-half with split-halves layout."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([-x2, x1], axis=-1)
    return x * cos[None, :, None, :] + rotated * sin[None, :, None, :]


def causal_mask(seq: int, window: int) -> jax.Array:
    """[T, T] additive mask: 0 allowed, -1e30 disallowed."""
    i = jnp.arange(seq)[:, None]
    j = jnp.arange(seq)[None, :]
    allowed = j <= i
    if window > 0:
        allowed = allowed & (i - j < window)
    return jnp.where(allowed, 0.0, -1e30).astype(jnp.float32)


def attention(cfg: ModelConfig, q, k, v, mask) -> jax.Array:
    """q,k,v: [B, T, H, hd] → [B, T, H*hd]."""
    scale = 1.0 / math.sqrt(cfg.head_dim)
    logits = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    logits = logits + mask[None, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v)
    b, t = out.shape[0], out.shape[1]
    return out.reshape(b, t, cfg.d_model)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

# Calibration taps per block: each is the input activation of one or more
# compressible linears (wq/wk/wv share attn_in, w_gate/w_up share mlp_in).
def tap_names(cfg: ModelConfig) -> list[str]:
    taps = []
    for i in range(cfg.n_layers):
        taps += [f"blocks.{i}.attn_in", f"blocks.{i}.attn_out_in",
                 f"blocks.{i}.mlp_in", f"blocks.{i}.mlp_down_in"]
    return taps


def tap_for_linear(name: str) -> str:
    """Map a compressible weight name to the tap that feeds it."""
    block, leaf = name.rsplit(".", 2)[0], name.rsplit(".", 1)[1]
    if leaf in ("wq", "wk", "wv"):
        return f"{block}.attn_in"
    if leaf == "wo":
        return f"{block}.attn_out_in"
    if leaf in ("w_gate", "w_up", "fc1"):
        return f"{block}.mlp_in"
    return f"{block}.mlp_down_in"  # w_down / fc2


def _forward(cfg: ModelConfig, params, tokens, apply_linear, collect=None):
    """Shared forward skeleton.

    ``apply_linear(name, x2d)`` implements ``x @ W[name]`` (dense or factored);
    ``collect(tap_name, x2d)`` records activations when not None.
    """
    b, t = tokens.shape
    x = params["tok_emb"][tokens]
    if cfg.family == "opt":
        x = x + params["pos_emb"][None, :t, :]
    mask = causal_mask(t, cfg.window)
    cos, sin = rope_tables(t, cfg.head_dim)
    use_rope = cfg.family in ("llama", "mistral")

    def lin(name, h2d):
        if collect is not None:
            collect(tap_for_linear(name), h2d)
        return apply_linear(name, h2d)

    for i in range(cfg.n_layers):
        # --- attention ---
        h = _norm(cfg, params, f"blocks.{i}.attn_norm", x)
        h2 = h.reshape(b * t, cfg.d_model)
        q = lin(f"blocks.{i}.attn.wq", h2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = lin(f"blocks.{i}.attn.wk", h2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = lin(f"blocks.{i}.attn.wv", h2).reshape(b, t, cfg.n_heads, cfg.head_dim)
        if use_rope:
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        att = attention(cfg, q, k, v, mask)
        att2 = att.reshape(b * t, cfg.d_model)
        o = lin(f"blocks.{i}.attn.wo", att2).reshape(b, t, cfg.d_model)
        x = x + o
        # --- MLP ---
        h = _norm(cfg, params, f"blocks.{i}.mlp_norm", x)
        h2 = h.reshape(b * t, cfg.d_model)
        if cfg.family == "opt":
            u = jax.nn.relu(lin(f"blocks.{i}.mlp.fc1", h2))
            m = lin(f"blocks.{i}.mlp.fc2", u)
        else:
            g = jax.nn.silu(lin(f"blocks.{i}.mlp.w_gate", h2))
            u = lin(f"blocks.{i}.mlp.w_up", h2)
            m = lin(f"blocks.{i}.mlp.w_down", g * u)
        x = x + m.reshape(b, t, cfg.d_model)

    if cfg.family == "opt":
        x = layernorm(x, params["final_norm.w"], params["final_norm.b"])
    else:
        x = rmsnorm(x, params["final_norm.w"])
    logits = x @ params["lm_head"]
    return logits


def _nll(logits: jax.Array, tokens: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Next-token sum NLL and token count over the batch."""
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    targets = tokens[:, 1:]
    picked = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    sum_nll = -jnp.sum(picked)
    count = jnp.array(targets.size, jnp.float32)
    return sum_nll.astype(jnp.float32), count


def loss_fn(cfg: ModelConfig, params, tokens):
    """Dense forward → (sum_nll, token_count)."""
    dense = lambda name, h2d: h2d @ params[name]
    logits = _forward(cfg, params, tokens, dense)
    return _nll(logits, tokens)


def logits_fn(cfg: ModelConfig, params, tokens):
    """Dense forward → logits [B, T, vocab] (used by parity tests/serving)."""
    dense = lambda name, h2d: h2d @ params[name]
    return _forward(cfg, params, tokens, dense)


def loss_and_grams_fn(cfg: ModelConfig, params, tokens):
    """Dense forward returning (sum_nll, count, grams, abssums) where
    ``grams[tap]`` is ``XᵀX`` ([n, n]) and ``abssums[tap]`` is the per-column
    ``Σ|x|`` ([1, n]), both accumulated over batch·seq rows by the L1 Pallas
    kernel.  The Gram feeds ASVD-I/II whitening; the abs-sum feeds ASVD-0."""
    grams: dict[str, jax.Array] = {}
    abssums: dict[str, jax.Array] = {}

    def collect(tap, h2d):
        if tap not in grams:
            grams[tap], abssums[tap] = gram_kernel.gram(h2d)

    dense = lambda name, h2d: h2d @ params[name]
    logits = _forward(cfg, params, tokens, dense, collect=collect)
    sum_nll, count = _nll(logits, tokens)
    return sum_nll, count, grams, abssums


def lowrank_loss_fn(cfg: ModelConfig, params, factors, tokens):
    """Forward with every compressible weight replaced by nested factors.

    ``factors[name] = (P1 [n,k1m], Q1 [k1m,m], P2 [n,k2m], Q2 [k2m,m])``
    zero-padded to the fixed max ranks; non-compressed params (embeddings,
    norms, lm_head) come from ``params``.
    """
    def apply_linear(name, h2d):
        if name in factors:
            p1, q1, p2, q2 = factors[name]
            return lowrank_kernel.nested_apply(h2d, p1, q1, p2, q2)
        return h2d @ params[name]

    logits = _forward(cfg, params, tokens, apply_linear)
    return _nll(logits, tokens)


def lowrank_rowloss_fn(cfg: ModelConfig, params, factors, tokens):
    """Serving variant of the factored forward: per-ROW (sum_nll, count)
    vectors [B] so the dynamic batcher can score independent requests in one
    execution and discard padding rows."""
    def apply_linear(name, h2d):
        if name in factors:
            p1, q1, p2, q2 = factors[name]
            return lowrank_kernel.nested_apply(h2d, p1, q1, p2, q2)
        return h2d @ params[name]

    logits = _forward(cfg, params, tokens, apply_linear)
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    targets = tokens[:, 1:]
    picked = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    row_nll = -jnp.sum(picked, axis=1)  # [B]
    row_count = jnp.full((tokens.shape[0],), tokens.shape[1] - 1, jnp.float32)
    return row_nll.astype(jnp.float32), row_count


def max_ranks(n_in: int, n_out: int) -> tuple[int, int]:
    """Padded factor ranks for a weight of shape [n_in, n_out].

    ``k_budget(ρ) = (1-ρ)·m·n/(m+n)``; the largest k any experiment uses is
    at the smallest ratio (10%).  k2 is at most (1-α_min)=0.25 of the budget.
    Must match `rust/src/compress/ranks.rs`.
    """
    kmax = int((1.0 - 0.10) * n_in * n_out / (n_in + n_out))
    k1max = max(1, kmax)
    k2max = max(1, math.ceil(0.25 * kmax))
    return k1max, k2max


def zero_factors(cfg: ModelConfig) -> dict[str, tuple[jax.Array, ...]]:
    """All-zero padded factor set (shape template for AOT lowering)."""
    out = {}
    for name, (n_in, n_out) in linear_shapes(cfg).items():
        k1m, k2m = max_ranks(n_in, n_out)
        out[name] = (
            jnp.zeros((n_in, k1m), jnp.float32),
            jnp.zeros((k1m, n_out), jnp.float32),
            jnp.zeros((n_in, k2m), jnp.float32),
            jnp.zeros((k2m, n_out), jnp.float32),
        )
    return out
