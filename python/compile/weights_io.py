"""NSVDW — the weight interchange format between JAX training and Rust.

Layout (little-endian):

    magic   b"NSVDW001"
    u32     n_tensors
    repeat n_tensors times:
        u16     name_len
        bytes   name (utf-8)
        u8      ndim
        u32[ndim] dims
        f32[prod(dims)] data, row-major (C order)

Reader lives in rust/src/model/weights.rs and must match byte-for-byte.
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"NSVDW001"


def save_weights(path: Path, params: dict) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(params)))
        for name in sorted(params):
            arr = np.asarray(params[name], dtype=np.float32)
            name_b = name.encode("utf-8")
            f.write(struct.pack("<H", len(name_b)))
            f.write(name_b)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(np.ascontiguousarray(arr).tobytes())


def load_weights(path: Path) -> dict:
    path = Path(path)
    out = {}
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad NSVDW magic")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            count = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * count), dtype="<f4")
            out[name] = data.reshape(dims).copy()
    return out
