"""Synthetic multilingual corpora — the dataset substrate.

The paper evaluates on eight real datasets (WikiText-2, PTB, C4, SNIPS,
AlpacaEval, MCTest, CMRC (CN), AlpacaEval (JP)).  None are available in this
offline environment, so each is substituted with a seeded synthetic byte-level
corpus that preserves the property the paper's experiments depend on:

* the six English-like domains share an alphabet but differ in vocabulary and
  structure (activation cosine similarity vs the calibration set between
  ~0.5 and ~0.95 — Table 2's English block);
* the CN/JP domains are built from CJK/hiragana UTF-8 byte ranges, so with a
  byte tokenizer they occupy a disjoint input region (similarity < 0.5 —
  Table 2's multilingual block).  That disjointness is the mechanism NSVD
  exploits: the calibration Gram carries almost no mass in those directions,
  and the plain-SVD second stage of the nested decomposition recovers it.

Each domain is a small Markov process over a domain-specific word list with
domain-specific punctuation/structure.  Everything is deterministic given the
seed.  Generated once at `make artifacts`; both the JAX training loop and the
Rust evaluation read the emitted token files.

Token file format (`.tok`): magic b"NSVDTOK1", u32 LE count, then `count`
bytes of token ids (vocab = 256, byte-level).
"""

from __future__ import annotations

import random
import struct
from dataclasses import dataclass
from pathlib import Path

MAGIC = b"NSVDTOK1"
VOCAB = 256

# ---------------------------------------------------------------------------
# Domain definitions
# ---------------------------------------------------------------------------

_WIKI_WORDS = (
    "the history of early modern state was established in century under "
    "dynasty empire river city population region known first large system "
    "government university research science theory developed during between "
    "world national culture language tradition period army battle treaty "
    "king province island mountain climate economy industry railway museum"
).split()

_NEWS_WORDS = (
    "the market shares rose fell percent points trading stocks investors "
    "company said earnings quarter billion million revenue profit chairman "
    "federal bank rates policy economy growth index futures analysts report "
    "prices dollar yen bond treasury yield exchange commission securities"
).split()

_WEB_WORDS = (
    "click here free online best new home page site web email search data "
    "service products shop price buy now review guide how what when your "
    "top list tips blog post comments share video photo news today update "
    "the and for with this that from more about contact privacy terms help"
).split()

_SNIPS_WORDS = (
    "play music song artist album playlist weather forecast tomorrow today "
    "rain snow temperature book restaurant table reservation movie showtimes "
    "theatre nearby find search add remind alarm set timer turn lights off "
    "on volume next previous stop resume what is the in for me my at"
).split()

_ALPACA_WORDS = (
    "write explain describe summarize list generate create translate given "
    "following sentence paragraph essay code function python story poem "
    "instruction response input output task answer question provide example "
    "steps how improve rewrite classify identify the a an please that this"
).split()

_MCTEST_WORDS = (
    "once upon time little boy girl dog cat went home school friend mother "
    "father played happy sad found lost ball tree park day night said asked "
    "wanted liked ran jumped saw big small red blue then they because very "
    "the and was were had his her one two three story end smiled laughed"
).split()

# CJK-like syllables: two-byte pairs drawn from common CJK UTF-8 lead bytes.
# We synthesize "words" as 1-3 CJK characters; each char is a 3-byte UTF-8
# sequence 0xE4-0xE9 0x80-0xBF 0x80-0xBF.
_JP_HIRAGANA = [chr(cp) for cp in range(0x3041, 0x3097)]  # ぁ..ゖ  (0xE3 lead)
_JP_KATAKANA = [chr(cp) for cp in range(0x30A1, 0x30FB)]


@dataclass
class DomainSpec:
    name: str
    kind: str  # "english" | "cjk" | "jp"
    words: list | None
    seed: int
    # Markov bigram temperature: lower = more repetitive/structured.
    order_strength: float = 0.7


DOMAINS = [
    DomainSpec("wiki", "english", _WIKI_WORDS, 101, 0.75),
    DomainSpec("ptb", "english", _NEWS_WORDS, 202, 0.65),
    DomainSpec("c4", "english", _WEB_WORDS, 303, 0.55),
    DomainSpec("snips", "english", _SNIPS_WORDS, 404, 0.80),
    DomainSpec("alpaca", "english", _ALPACA_WORDS, 505, 0.70),
    DomainSpec("mctest", "english", _MCTEST_WORDS, 606, 0.85),
    DomainSpec("cmrc_cn", "cjk", None, 707, 0.70),
    DomainSpec("alpaca_jp", "jp", None, 808, 0.70),
]

DOMAIN_NAMES = [d.name for d in DOMAINS]


def _markov_text(spec: DomainSpec, rng: random.Random, n_chars: int) -> str:
    """English-like text from a first-order Markov chain over the word list."""
    words = spec.words
    v = len(words)
    # Deterministic sparse bigram preference matrix: each word prefers a
    # domain-seeded subset of successors.
    pref = {}
    for i in range(v):
        r = random.Random(spec.seed * 7919 + i)
        succ = [r.randrange(v) for _ in range(4)]
        pref[i] = succ
    out = []
    total = 0
    cur = rng.randrange(v)
    sent_len = 0
    while total < n_chars:
        word = words[cur]
        out.append(word)
        total += len(word) + 1
        sent_len += 1
        if sent_len >= rng.randint(6, 18):
            out[-1] = out[-1] + rng.choice([".", ".", ".", "?", "!"])
            sent_len = 0
        if rng.random() < spec.order_strength:
            cur = rng.choice(pref[cur])
        else:
            cur = rng.randrange(v)
    return " ".join(out)


def _cjk_text(spec: DomainSpec, rng: random.Random, n_chars: int) -> str:
    """CJK-like text: 3-byte UTF-8 chars from the common ideograph planes,
    grouped into 1-3 char 'words', punctuated with fullwidth marks."""
    # Character inventory: a domain-seeded subset of plausible codepoints,
    # Zipf-weighted like real hanzi usage.
    r = random.Random(spec.seed)
    inventory = [chr(r.randrange(0x4E00, 0x9FA5)) for _ in range(400)]
    weights = [1.0 / (i + 1) ** 0.8 for i in range(len(inventory))]
    out = []
    total = 0
    sent = 0
    while total < n_chars:
        wlen = rng.choices([1, 2, 3], weights=[3, 5, 2])[0]
        word = "".join(rng.choices(inventory, weights=weights, k=wlen))
        out.append(word)
        total += 3 * wlen
        sent += 1
        if sent >= rng.randint(8, 20):
            out.append("。")
            total += 3
            sent = 0
        elif rng.random() < 0.1:
            out.append("，")
            total += 3
    return "".join(out)


def _jp_text(spec: DomainSpec, rng: random.Random, n_chars: int) -> str:
    """Japanese-like text: hiragana-heavy with katakana loanwords and a few
    ASCII digits, reproducing the mixed-script profile of AlpacaEval (JP)."""
    out = []
    total = 0
    sent = 0
    while total < n_chars:
        roll = rng.random()
        if roll < 0.75:
            wlen = rng.randint(2, 5)
            word = "".join(rng.choices(_JP_HIRAGANA, k=wlen))
        elif roll < 0.92:
            wlen = rng.randint(2, 5)
            word = "".join(rng.choices(_JP_KATAKANA, k=wlen))
        else:
            word = str(rng.randint(0, 99))
        out.append(word)
        total += sum(len(c.encode("utf-8")) for c in word)
        sent += 1
        if sent >= rng.randint(6, 14):
            out.append("。")
            total += 3
            sent = 0
    return "".join(out)


def generate_domain(spec: DomainSpec, n_bytes: int, stream_seed: int | None = None) -> bytes:
    """Generate ~n_bytes of UTF-8 text for a domain and return its bytes.

    The domain *structure* (word inventories, bigram preferences) is always
    derived from ``spec.seed``; ``stream_seed`` only varies the sampling walk.
    Train and test splits therefore share a distribution (like WikiText-2's
    train/test: activation similarity ≈ 0.94) while containing different text.
    """
    rng = random.Random(spec.seed if stream_seed is None else stream_seed)
    if spec.kind == "english":
        text = _markov_text(spec, rng, n_bytes)
    elif spec.kind == "cjk":
        text = _cjk_text(spec, rng, n_bytes)
    elif spec.kind == "jp":
        text = _jp_text(spec, rng, n_bytes)
    else:  # pragma: no cover - guarded by DomainSpec construction
        raise ValueError(f"unknown domain kind {spec.kind}")
    return text.encode("utf-8")[:n_bytes]


def tokenize(data: bytes) -> list[int]:
    """Byte-level tokenizer: token id = byte value (vocab 256)."""
    return list(data)


def write_tokens(path: Path, tokens: list[int]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tokens)))
        f.write(bytes(tokens))


def read_tokens(path: Path) -> list[int]:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (count,) = struct.unpack("<I", f.read(4))
        data = f.read(count)
        if len(data) != count:
            raise ValueError(f"{path}: truncated ({len(data)} of {count})")
        return list(data)


def build_all(out_dir: Path, train_bytes: int = 262144, test_bytes: int = 65536) -> dict:
    """Generate train/test splits for all domains.  Returns {name: paths}."""
    manifest = {}
    for spec in DOMAINS:
        # Train and test are disjoint sampling walks over the SAME domain
        # structure, mirroring the paper's train/test splits.
        train = generate_domain(spec, train_bytes, stream_seed=spec.seed)
        test = generate_domain(spec, test_bytes, stream_seed=spec.seed + 5000)
        train_path = out_dir / f"{spec.name}.train.tok"
        test_path = out_dir / f"{spec.name}.test.tok"
        write_tokens(train_path, tokenize(train))
        write_tokens(test_path, tokenize(test))
        manifest[spec.name] = {
            "train": str(train_path),
            "test": str(test_path),
            "kind": spec.kind,
        }
    return manifest
