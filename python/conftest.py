"""Make `compile.*` importable when pytest runs from the repo root
(`pytest python/tests/`) as well as from python/ (`pytest tests/`)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
