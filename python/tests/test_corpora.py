"""Corpora substrate: determinism, byte-range separation, token file format."""

import collections
from pathlib import Path

import pytest

from compile import corpora


def test_deterministic_generation():
    spec = corpora.DOMAINS[0]
    a = corpora.generate_domain(spec, 4096)
    b = corpora.generate_domain(spec, 4096)
    assert a == b


def test_train_test_same_distribution_different_text():
    spec = corpora.DOMAINS[0]
    train = corpora.generate_domain(spec, 8192, stream_seed=spec.seed)
    test = corpora.generate_domain(spec, 8192, stream_seed=spec.seed + 5000)
    assert train != test
    # Shared unigram structure: top bytes overlap heavily.
    top = lambda data: set(b for b, _ in collections.Counter(data).most_common(20))
    overlap = len(top(train) & top(test)) / 20.0
    assert overlap > 0.7, f"train/test unigram overlap {overlap}"


def test_english_domains_are_ascii():
    for spec in corpora.DOMAINS:
        if spec.kind != "english":
            continue
        data = corpora.generate_domain(spec, 4096)
        assert all(b < 128 for b in data), spec.name


def test_cjk_jp_occupy_high_byte_ranges():
    """The multilingual mechanism: CN/JP corpora must be dominated by bytes
    the English calibration set never produces (Table 2's <0.5 similarity)."""
    for name in ("cmrc_cn", "alpaca_jp"):
        spec = next(d for d in corpora.DOMAINS if d.name == name)
        data = corpora.generate_domain(spec, 8192)
        high = sum(1 for b in data if b >= 128)
        assert high / len(data) > 0.8, f"{name}: high-byte share {high/len(data)}"


def test_cn_and_jp_differ_in_lead_bytes():
    cn = corpora.generate_domain(
        next(d for d in corpora.DOMAINS if d.name == "cmrc_cn"), 8192)
    jp = corpora.generate_domain(
        next(d for d in corpora.DOMAINS if d.name == "alpaca_jp"), 8192)
    # Hiragana/katakana live in the 0xE3 lead-byte plane; hanzi in 0xE4-0xE9.
    cn_e3 = sum(1 for b in cn if b == 0xE3) / len(cn)
    jp_e3 = sum(1 for b in jp if b == 0xE3) / len(jp)
    assert jp_e3 > 0.15
    assert cn_e3 < 0.05


def test_domains_have_distinct_distributions():
    """Each English domain should differ from wiki (the calibration domain)
    but less than the CJK domains do (the Table 2 similarity ordering)."""
    def hist(data):
        c = collections.Counter(data)
        total = sum(c.values())
        return {b: c[b] / total for b in c}

    def cosine(h1, h2):
        keys = set(h1) | set(h2)
        dot = sum(h1.get(k, 0) * h2.get(k, 0) for k in keys)
        n1 = sum(v * v for v in h1.values()) ** 0.5
        n2 = sum(v * v for v in h2.values()) ** 0.5
        return dot / (n1 * n2)

    wiki = hist(corpora.generate_domain(corpora.DOMAINS[0], 16384))
    sims = {}
    for spec in corpora.DOMAINS[1:]:
        sims[spec.name] = cosine(wiki, hist(corpora.generate_domain(spec, 16384)))
    for name in ("ptb", "c4", "snips", "alpaca", "mctest"):
        assert sims[name] > 0.5, f"{name} sim {sims[name]}"
    for name in ("cmrc_cn", "alpaca_jp"):
        assert sims[name] < 0.3, f"{name} sim {sims[name]}"


def test_token_file_roundtrip(tmp_path: Path):
    toks = list(range(256)) * 3
    path = tmp_path / "x.tok"
    corpora.write_tokens(path, toks)
    back = corpora.read_tokens(path)
    assert back == toks


def test_token_file_rejects_bad_magic(tmp_path: Path):
    path = tmp_path / "bad.tok"
    path.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(ValueError):
        corpora.read_tokens(path)


def test_build_all_writes_all_domains(tmp_path: Path):
    manifest = corpora.build_all(tmp_path, train_bytes=2048, test_bytes=512)
    assert set(manifest) == set(corpora.DOMAIN_NAMES)
    for name, meta in manifest.items():
        train = corpora.read_tokens(Path(meta["train"]))
        test = corpora.read_tokens(Path(meta["test"]))
        assert len(train) == 2048
        assert len(test) == 512
