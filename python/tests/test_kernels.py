"""L1 kernel correctness: Pallas vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-multiple-of-block edges) and value
scales; allclose against `kernels.ref`.  This is the CORE correctness signal
for the artifact chain: the lowered HLO embeds exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, lowrank, matmul, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 96),
    n=st.integers(1, 200),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_matmul_matches_ref(m, k, n, scale):
    x = _rand(0, (m, k), scale)
    w = _rand(1, (k, n))
    out = matmul.matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4 * scale)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 400),
    n=st.integers(1, 160),
    bm=st.sampled_from([32, 128]),
)
def test_gram_matches_ref(m, n, bm):
    x = _rand(2, (m, n))
    g, a = gram.gram(x, bm=bm)
    g_ref, a_ref = ref.gram_ref(x)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(a, a_ref, rtol=1e-4, atol=1e-3)


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    n=st.integers(2, 96),
    mout=st.integers(2, 96),
    k1=st.integers(1, 48),
    k2=st.integers(1, 12),
)
def test_nested_apply_matches_ref(rows, n, mout, k1, k2):
    x = _rand(3, (rows, n))
    p1 = _rand(4, (n, k1))
    q1 = _rand(5, (k1, mout))
    p2 = _rand(6, (n, k2))
    q2 = _rand(7, (k2, mout))
    out = lowrank.nested_apply(x, p1, q1, p2, q2)
    want = ref.nested_apply_ref(x, p1, q1, p2, q2)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-3)


def test_nested_apply_zero_padding_is_identity():
    """Zero-padded rank columns must contribute exactly nothing — the
    property the single fixed-shape serving executable relies on."""
    x = _rand(8, (64, 32))
    p1 = _rand(9, (32, 10))
    q1 = _rand(10, (10, 24))
    # Pad stage-1 to rank 16 with zeros, stage-2 entirely zero.
    p1_pad = jnp.concatenate([p1, jnp.zeros((32, 6))], axis=1)
    q1_pad = jnp.concatenate([q1, jnp.zeros((6, 24))], axis=0)
    p2 = jnp.zeros((32, 4))
    q2 = jnp.zeros((4, 24))
    out = lowrank.nested_apply(x, p1_pad, q1_pad, p2, q2)
    want = ref.matmul_ref(x, p1 @ q1)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


def test_gram_accumulation_is_row_partitionable():
    """Gram of stacked rows = sum of per-chunk Grams (streaming invariant
    the Rust calibration collector depends on)."""
    x1 = _rand(11, (100, 20))
    x2 = _rand(12, (60, 20))
    g_all, a_all = gram.gram(jnp.concatenate([x1, x2], axis=0))
    g1, a1 = gram.gram(x1)
    g2, a2 = gram.gram(x2)
    np.testing.assert_allclose(g_all, g1 + g2, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(a_all, a1 + a2, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bm", [1, 7, 64, 999])
def test_matmul_odd_block_sizes(bm):
    x = _rand(13, (65, 33))
    w = _rand(14, (33, 17))
    out = matmul.matmul(x, w, bm=bm, bn=16)
    np.testing.assert_allclose(out, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-4)
