"""NSVDW interchange format: roundtrip + layout pinning for the Rust reader."""

import struct
from pathlib import Path

import numpy as np
import pytest

from compile.weights_io import MAGIC, load_weights, save_weights


def test_roundtrip(tmp_path: Path):
    params = {
        "a.w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1.5], dtype=np.float32),
        "c.scalar": np.float32(2.5),
    }
    path = tmp_path / "m.nsvdw"
    save_weights(path, params)
    back = load_weights(path)
    assert set(back) == set(params)
    np.testing.assert_array_equal(back["a.w"], params["a.w"])
    np.testing.assert_array_equal(back["b"], params["b"])
    assert float(back["c.scalar"]) == 2.5


def test_binary_layout_is_pinned(tmp_path: Path):
    """Byte-level pin so the Rust reader (model/weights.rs) cannot drift."""
    params = {"w": np.array([[1.0, 2.0]], dtype=np.float32)}
    path = tmp_path / "pin.nsvdw"
    save_weights(path, params)
    raw = path.read_bytes()
    assert raw[:8] == MAGIC
    (n,) = struct.unpack_from("<I", raw, 8)
    assert n == 1
    (name_len,) = struct.unpack_from("<H", raw, 12)
    assert name_len == 1
    assert raw[14:15] == b"w"
    ndim = raw[15]
    assert ndim == 2
    dims = struct.unpack_from("<II", raw, 16)
    assert dims == (1, 2)
    vals = struct.unpack_from("<ff", raw, 24)
    assert vals == (1.0, 2.0)
    assert len(raw) == 24 + 8


def test_names_are_sorted_on_disk(tmp_path: Path):
    params = {"z": np.zeros(1, np.float32), "a": np.ones(1, np.float32)}
    path = tmp_path / "s.nsvdw"
    save_weights(path, params)
    raw = path.read_bytes()
    assert raw.find(b"\x01\x00a") < raw.find(b"\x01\x00z")


def test_rejects_bad_magic(tmp_path: Path):
    path = tmp_path / "bad.nsvdw"
    path.write_bytes(b"WRONG!!!" + b"\x00" * 8)
    with pytest.raises(ValueError):
        load_weights(path)
