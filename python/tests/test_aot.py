"""AOT lowering smoke tests: HLO text is produced and structurally sound."""

import jax
import pytest

from compile import aot, model
from compile.weights_io import save_weights, load_weights


@pytest.fixture(scope="module")
def tiny_params(tmp_path_factory):
    cfg = model.CONFIGS["llama-t"]
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    path = tmp_path_factory.mktemp("w") / "llama-t.nsvdw"
    save_weights(path, {k: v for k, v in params.items()})
    return cfg, load_weights(path)


def test_lower_dense_produces_hlo_text(tiny_params):
    cfg, params = tiny_params
    hlo = aot.lower_dense(cfg, params, batch=1)
    assert "ENTRY" in hlo and "HloModule" in hlo
    # One i32 token parameter + one f32 parameter per weight tensor.
    assert hlo.count("parameter(") >= len(params) + 1


def test_lower_gram_outputs_grams_and_abssums(tiny_params):
    cfg, params = tiny_params
    hlo, taps = aot.lower_gram(cfg, params, batch=1)
    assert len(taps) == 4 * cfg.n_layers
    assert "ENTRY" in hlo
    # Output tuple: 2 scalars + gram + abssum per tap.
    assert f"f32[{cfg.d_model},{cfg.d_model}]" in hlo


def test_lower_lowrank_has_factor_parameters(tiny_params):
    cfg, params = tiny_params
    hlo, worder, ranks, names = aot.lower_lowrank(cfg, params, batch=1)
    n_weights = len(model.linear_shapes(cfg))
    assert len(worder) == n_weights
    assert worder == sorted(worder)
    # The dense copies of compressed weights are NOT parameters (jax would
    # prune them and break positional marshaling on the rust side).
    assert set(names).isdisjoint(set(worder))
    assert len(names) == len(params) - n_weights
    for w, (k1m, k2m) in ranks.items():
        n_in, n_out = model.linear_shapes(cfg)[w]
        assert (k1m, k2m) == model.max_ranks(n_in, n_out)
    assert hlo.count("parameter(") >= len(names) + 4 * n_weights + 1


def test_lower_serve_emits_row_outputs(tiny_params):
    cfg, params = tiny_params
    hlo, worder, _ranks, names = aot.lower_serve(cfg, params, batch=4)
    assert "ENTRY" in hlo
    assert set(names).isdisjoint(set(worder))
    # Per-row outputs: two f32[4] vectors in the result tuple.
    assert "f32[4]" in hlo


def test_sources_digest_is_stable():
    assert aot._sources_digest() == aot._sources_digest()
    assert len(aot._sources_digest()) == 16
