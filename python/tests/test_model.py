"""L2 model invariants: shapes, families, factored-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _toks(b=2, t=32, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, model.VOCAB)


@pytest.mark.parametrize("name", list(model.CONFIGS))
def test_forward_shapes_and_finite_loss(name):
    cfg = model.CONFIGS[name]
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    toks = _toks()
    logits = model.logits_fn(cfg, params, toks)
    assert logits.shape == (2, 32, cfg.vocab)
    sum_nll, count = model.loss_fn(cfg, params, toks)
    assert count == 2 * 31
    mean = float(sum_nll) / float(count)
    assert np.isfinite(mean)
    # Random init ≈ uniform over 256 tokens → NLL near ln(256) ≈ 5.55.
    assert 4.0 < mean < 7.0


def test_causal_mask_blocks_future():
    m = model.causal_mask(5, 0)
    assert float(m[0, 1]) < -1e20
    assert float(m[4, 0]) == 0.0
    mw = model.causal_mask(5, 2)
    assert float(mw[4, 1]) < -1e20  # outside window
    assert float(mw[4, 3]) == 0.0


def test_causality_property():
    """Changing a future token must not change past logits."""
    cfg = model.CONFIGS["llama-t"]
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    toks = _toks(1, 16, seed=3)
    logits_a = model.logits_fn(cfg, params, toks)
    toks_b = toks.at[0, 10].set((toks[0, 10] + 7) % 256)
    logits_b = model.logits_fn(cfg, params, toks_b)
    np.testing.assert_allclose(
        logits_a[0, :10], logits_b[0, :10], rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(logits_a[0, 10:], logits_b[0, 10:])


def test_rope_preserves_norm():
    cos, sin = model.rope_tables(16, 32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 2, 32))
    y = model.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
        rtol=1e-5, atol=1e-5,
    )


def test_sliding_window_differs_beyond_window():
    cfg_m = model.CONFIGS["mistral-t"]
    cfg_l = model.CONFIGS["llama-t"]
    params = model.init_params(cfg_l, jax.random.PRNGKey(5))
    toks = _toks(1, 128, seed=6)  # window=32 < T
    la = model.logits_fn(cfg_l, params, toks)
    lm = model.logits_fn(cfg_m, params, toks)
    # Same weights, same block structure: only the mask differs, and only
    # for positions ≥ window.
    np.testing.assert_allclose(la[0, :32], lm[0, :32], rtol=1e-4, atol=1e-4)
    assert not np.allclose(la[0, 100:], lm[0, 100:])


def test_grams_match_direct_accumulation():
    cfg = model.CONFIGS["llama-t"]
    params = model.init_params(cfg, jax.random.PRNGKey(7))
    toks = _toks(2, 32, seed=8)
    _, _, grams, abssums = model.loss_and_grams_fn(cfg, params, toks)
    assert set(grams) == set(model.tap_names(cfg))
    for tap, g in grams.items():
        n = g.shape[0]
        assert g.shape == (n, n)
        # Gram is symmetric PSD.
        np.testing.assert_allclose(g, g.T, rtol=1e-4, atol=1e-3)
        evals = np.linalg.eigvalsh(np.asarray(g))
        assert evals.min() > -1e-2
        assert abssums[tap].shape == (1, n)
        assert float(abssums[tap].min()) >= 0.0


def test_lowrank_forward_with_exact_factors_matches_dense():
    """Factoring each weight exactly (full-rank SVD split) and padding to the
    max ranks must reproduce the dense forward — the end-to-end validation of
    the padded-rank executable trick."""
    cfg = model.CONFIGS["llama-t"]
    params = model.init_params(cfg, jax.random.PRNGKey(9))
    toks = _toks(1, 16, seed=10)
    shapes = model.linear_shapes(cfg)
    factors = {}
    for name, (n_in, n_out) in shapes.items():
        w = np.asarray(params[name])
        u, s, vt = np.linalg.svd(w, full_matrices=False)
        k1m, k2m = model.max_ranks(n_in, n_out)
        r = min(len(s), k1m)
        p1 = np.zeros((n_in, k1m), np.float32)
        q1 = np.zeros((k1m, n_out), np.float32)
        p1[:, :r] = u[:, :r] * np.sqrt(s[:r])
        q1[:r, :] = (vt[:r, :].T * np.sqrt(s[:r])).T
        p2 = np.zeros((n_in, k2m), np.float32)
        q2 = np.zeros((k2m, n_out), np.float32)
        # Residual beyond k1m into stage 2 (if any).
        r2 = min(len(s) - r, k2m)
        if r2 > 0:
            p2[:, :r2] = u[:, r:r + r2] * np.sqrt(s[r:r + r2])
            q2[:r2, :] = (vt[r:r + r2, :].T * np.sqrt(s[r:r + r2])).T
        factors[name] = tuple(jnp.asarray(a) for a in (p1, q1, p2, q2))
    nll_lr, cnt_lr = model.lowrank_loss_fn(cfg, params, factors, toks)
    nll_d, cnt_d = model.loss_fn(cfg, params, toks)
    assert cnt_lr == cnt_d
    # d=128 weights have rank ≤ 128 but k1m+k2m = 72 < 128, so exact equality
    # is impossible; with random-init (near-isotropic) weights the truncation
    # changes the loss slightly.  Use trained-weight-free tolerance: compare
    # against the dense loss of the truncated reconstruction instead.
    recon_params = dict(params)
    for name in shapes:
        p1, q1, p2, q2 = factors[name]
        recon_params[name] = p1 @ q1 + p2 @ q2
    nll_recon, _ = model.loss_fn(cfg, recon_params, toks)
    np.testing.assert_allclose(float(nll_lr), float(nll_recon), rtol=1e-3)


def test_max_ranks_match_rust_contract():
    """Pin the rank formula (must match rust/src/compress/ranks.rs)."""
    assert model.max_ranks(128, 128) == (57, 15)
    assert model.max_ranks(128, 256) == (76, 19)
    import math
    k1m, k2m = model.max_ranks(384, 128)
    assert k1m == int(0.9 * 384 * 128 / (384 + 128))
    assert k2m == math.ceil(0.25 * k1m)
