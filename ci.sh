#!/usr/bin/env bash
# Repo CI: build, test, docs, formatting — run locally before every PR.
#
#   ./ci.sh          # full gate
#   ./ci.sh --quick  # skip the release build (debug test run only)
#
# Gates (in order, fail-fast):
#   1. cargo build --release        — the whole system compiles optimized
#   2. cargo test -q                — unit + integration tests (tier-1)
#   3. cargo bench --no-run         — every bench target compiles (the
#                                     paper-table regenerators rot silently
#                                     otherwise)
#   4. GEMM parity smoke            — perf_linalg's `gemm` benches in
#                                     --quick mode assert tiled == naive
#                                     and 4-worker bit-identity, so kernel
#                                     regressions fail fast
#   4b. SYRK + QR parity smokes     — perf_linalg's `syrk` benches assert
#                                     the packed SYRK upper triangle is
#                                     bit-identical to gemm_tn at workers
#                                     {1,4}; `qr_parity` asserts blocked
#                                     compact-WY QR == the retired
#                                     unblocked path (Q/R to rounding,
#                                     pivots exactly)
#   4c. tournament determinism      — the eig/svd tournament-ordering
#                                     tests (bit-identity across worker
#                                     counts incl. workers=4) run by name
#   4d. allocator smoke             — the global rank-allocator tests run
#                                     by name (budget exactness,
#                                     monotonicity, uniform parity,
#                                     worker-count determinism) plus
#                                     perf_allocate's greedy section in
#                                     --quick mode (asserts spectrum never
#                                     loses to uniform on the synthetic
#                                     model)
#   4e. serve smoke                 — the generation-server tests run by
#                                     name (paged KV pool allocator, prefix
#                                     trie, batched-step bit-parity incl.
#                                     chunked prefill and replay rows,
#                                     scheduler parity incl. preemption +
#                                     resume, streaming, and the randomized
#                                     32-seed serve-schedule fuzz grid)
#                                     plus perf_serve's parity section in
#                                     --quick mode (served tokens ==
#                                     sequential generate at batch {1,3,8}
#                                     × workers {1,4}, dense and
#                                     compressed); perf_serve also compiles
#                                     under the gate-3 `cargo bench
#                                     --no-run`
#   4f. paged-pool memory smoke     — perf_serve's `paged` section in
#                                     --quick mode: a pool at HALF the old
#                                     worst-case reservation must complete
#                                     every request AND sustain strictly
#                                     more concurrent sequences than
#                                     worst-case slot reservation fits in
#                                     the same memory (fault-in + prefix
#                                     sharing + preemption)
#   4g. int8 quantization smoke     — the quantization tests run by name
#                                     (RNE round-trip bound, byte
#                                     accounting, quantized-apply parity)
#                                     plus perf_linalg's `int8` section in
#                                     --quick mode: the tiled/SIMD i8×i8→i32
#                                     kernel must be bit-identical to the
#                                     naive i8 oracle at workers {1,4},
#                                     dispatched AND forced-scalar.  The
#                                     bench prints the detected CPU features
#                                     (dispatch tier + raw flags) so every
#                                     CI log records which microkernel ran
#   4h. robustness smoke            — the QoS scheduler tests run by name
#                                     (deadline kills, bounded-queue
#                                     rejection/shedding, no-priority-
#                                     inversion pin, per-tenant accounting,
#                                     watchdog fault isolation) plus the
#                                     chaos fuzz grid (32 seeds × fault
#                                     rates {0, 0.05, 0.2}: surviving
#                                     streams bit-exact, every casualty
#                                     exactly one correct terminal event,
#                                     scheduler never panics)
#   4i. compressed-KV-cache smoke   — the kv_compress tests run by name
#                                     (latent round-trip bound, kv-ratio 1.0
#                                     bit-identity pin, pool byte accounting,
#                                     batched-vs-sequential latent parity
#                                     incl. int8 factors, and the kv-ratio
#                                     serve fuzz grids) plus perf_serve's
#                                     `kv` section in --quick mode (serve
#                                     parity at kv-ratio 0.5 and the
#                                     >= 1.8x slots-at-equal-memory
#                                     admission assertion)
#   4j. observability smoke         — the obs tests run by name (span
#                                     nesting + parent linkage across
#                                     spawns, registry merge/replace
#                                     algebra, Prometheus + Chrome-trace
#                                     exporters, disabled-path inertness,
#                                     obs-on/off serve bit-identity) plus
#                                     the trace-export end-to-end smoke
#                                     (emitted JSON must round-trip
#                                     through util/json.rs with spans
#                                     from engine, kernel, and serve)
#   4k. bench regression gate       — BENCH_*.json baselines committed at
#                                     HEAD are extracted and compared
#                                     against the working tree's copies by
#                                     the bench_gate binary; any
#                                     higher-is-better metric down > 10%
#                                     (or lower-is-better up > 10%) fails.
#                                     Placeholder files (note contains
#                                     PLACEHOLDER, or empty results) are
#                                     skipped, so the gate arms itself only
#                                     once real numbers are committed
#   5. cargo doc --no-deps          — rustdoc builds with warnings DENIED,
#                                     so README/ARCHITECTURE/module docs
#                                     and intra-doc links can never rot
#                                     silently
#   6. cargo fmt --check            — advisory for now: the seed predates
#                                     rustfmt enforcement, so drift in
#                                     untouched files reports but does not
#                                     fail the gate.  Flip ADVISORY_FMT=0
#                                     once the tree is formatted.

set -euo pipefail
cd "$(dirname "$0")"

ADVISORY_FMT="${ADVISORY_FMT:-1}"
QUICK=0
[ "${1:-}" = "--quick" ] && QUICK=1

step() { printf '\n== %s ==\n' "$*"; }

if [ "$QUICK" -eq 0 ]; then
    step "cargo build --release"
    cargo build --release
fi

step "cargo test -q"
cargo test -q

step "cargo bench --no-run (bench targets compile)"
cargo bench --no-run

step "GEMM parity smoke (perf_linalg gemm --quick)"
cargo bench --bench perf_linalg -- gemm --quick

step "SYRK parity smoke (perf_linalg syrk --quick)"
cargo bench --bench perf_linalg -- syrk --quick

step "QR parity smoke (perf_linalg qr_parity --quick)"
cargo bench --bench perf_linalg -- qr_parity --quick

step "eig/svd tournament determinism (workers=4)"
cargo test -q tournament

step "allocator smoke (tests + perf_allocate greedy --quick)"
cargo test -q allocat
cargo bench --bench perf_allocate -- allocate_greedy --quick

step "serve smoke (generation-server tests + perf_serve parity --quick)"
cargo test -q serve
cargo bench --bench perf_serve -- parity --quick

step "paged-pool memory smoke (perf_serve paged --quick)"
cargo bench --bench perf_serve -- paged --quick

step "int8 quantization smoke (quant tests + perf_linalg int8 --quick)"
cargo test -q quant
cargo bench --bench perf_linalg -- int8 --quick

step "robustness smoke (QoS scheduler tests + chaos fuzz grid)"
cargo test -q deadline
cargo test -q shed
cargo test -q tenant
cargo test -q chaos
cargo test -q watchdog

step "compressed-KV-cache smoke (kv_compress tests + perf_serve kv --quick)"
cargo test -q kv_compress
cargo bench --bench perf_serve -- kv --quick

step "observability smoke (obs tests + trace-export end-to-end)"
cargo test -q obs
cargo test -q trace_export

step "bench regression gate (bench_gate vs HEAD baselines)"
BASELINE_DIR=target/bench_baseline
rm -rf "$BASELINE_DIR"
mkdir -p "$BASELINE_DIR"
for f in BENCH_*.json; do
    [ -e "$f" ] || continue
    # Compare against the committed baseline; a file not yet tracked at
    # HEAD (new suite) simply has no baseline and is skipped by the gate.
    git show "HEAD:$f" > "$BASELINE_DIR/$f" 2>/dev/null || rm -f "$BASELINE_DIR/$f"
done
cargo run -q --bin bench_gate -- "$BASELINE_DIR" . 0.10

step "cargo doc --no-deps (deny warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

step "cargo fmt --check"
if ! cargo fmt --check; then
    if [ "$ADVISORY_FMT" = "1" ]; then
        echo "WARNING: rustfmt drift (advisory; set ADVISORY_FMT=0 to enforce)"
    else
        echo "ERROR: rustfmt drift"
        exit 1
    fi
fi

printf '\nCI OK\n'
