// Build probe: gate the AVX-512 microkernels on toolchain support.
//
// The `std::arch` AVX-512 intrinsics stabilized in Rust 1.89; older
// toolchains must still build this crate (the runtime dispatcher then tops
// out at AVX2).  We probe `rustc --version` and emit `nsvd_avx512` only
// when the compiler is new enough — a pure version sniff, no network, no
// extra dependencies.

use std::process::Command;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var_os("RUSTC")?;
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc 2025-08-01)" → 89.  Nightly/beta suffixes parse
    // the same way; anything unparseable keeps the AVX-512 path off.
    let semver = text.split_whitespace().nth(1)?;
    let mut parts = semver.split(['.', '-']);
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    if major > 1 {
        return Some(u32::MAX);
    }
    if major == 1 {
        return Some(minor);
    }
    None
}

fn main() {
    println!("cargo:rustc-check-cfg=cfg(nsvd_avx512)");
    if rustc_minor().is_some_and(|m| m >= 89) {
        println!("cargo:rustc-cfg=nsvd_avx512");
    }
    println!("cargo:rerun-if-changed=build.rs");
}
